"""Streaming analytics — the paper's motivating scenario (Sec 1).

A producer ingests real-time events through the `repro.api` client while
an analytics reader repeatedly takes consistent range snapshots
("analytics while ingesting", Flurry-style).  Every scan is checked for
internal consistency: it must reflect exactly the prefix of ingested
batches visible at its snapshot timestamp — no torn reads, ever.

  PYTHONPATH=src python examples/streaming_analytics.py
"""

import numpy as np

from repro.api import Uruv, UruvConfig

EPOCHS = 12
BATCH = 256
WINDOW = 1000


def main():
    rng = np.random.default_rng(0)
    db = Uruv(UruvConfig(leaf_cap=32, max_leaves=2048, max_versions=1 << 17))

    ingested = 0
    for epoch in range(EPOCHS):
        # producer: BATCH new events keyed by arrival index, value = sensor
        keys = np.arange(ingested, ingested + BATCH, dtype=np.int32)
        db.insert(keys, rng.integers(0, 100, BATCH).astype(np.int32))
        ingested += BATCH

        # reader: consistent scan of the last WINDOW events — the snapshot
        # context registers the view and releases it on exit
        lo = max(0, ingested - WINDOW)
        with db.snapshot() as snap:
            window = db.range(lo, ingested - 1, snap)
        # consistency check: the scan contains EXACTLY the visible prefix
        got_keys = [k for k, _ in window]
        assert got_keys == list(range(lo, ingested)), "torn read!"
        hist = np.bincount([v for _, v in window], minlength=100)
        if epoch % 4 == 3:
            print(f"epoch {epoch+1:2d}: ingested={ingested:6d} "
                  f"window={len(window)} top-sensor={int(hist.argmax())} "
                  f"versions={int(db.store.n_vers)}")

        # retention: retire events older than 4 epochs, then GC
        if epoch % 4 == 3 and ingested > 4 * BATCH:
            horizon = ingested - 4 * BATCH
            db.delete(np.arange(max(0, horizon - BATCH), horizon,
                                dtype=np.int32))
            n_live = db.compact()
            print(f"          GC: {n_live} live events, "
                  f"versions={int(db.store.n_vers)}")

    print(f"all scans linearizable; {db.stats['device_passes']} device "
          "passes total; done.")


if __name__ == "__main__":
    main()
