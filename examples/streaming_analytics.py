"""Streaming analytics — the paper's motivating scenario (Sec 1).

A producer ingests real-time events into the store while an analytics
reader repeatedly takes consistent range snapshots ("analytics while
ingesting", Flurry-style).  Every scan is checked for internal consistency:
it must reflect exactly the prefix of ingested batches visible at its
snapshot timestamp — no torn reads, ever.

  PYTHONPATH=src python examples/streaming_analytics.py
"""

import numpy as np

from repro.core import batch as B
from repro.core import store as S


def main():
    rng = np.random.default_rng(0)
    st = S.create(S.UruvConfig(leaf_cap=32, max_leaves=8192,
                               max_versions=1 << 19))

    ingested = 0
    epoch_of_key = {}
    for epoch in range(20):
        # producer: 512 new events keyed by arrival index, value = sensor id
        keys = np.arange(ingested, ingested + 512, dtype=np.int32)
        vals = rng.integers(0, 100, 512).astype(np.int32)
        st, _ = B.apply_updates(st, keys, vals)
        for k in keys:
            epoch_of_key[int(k)] = epoch
        ingested += 512

        # reader: consistent scan of the last 2000 events
        st, snap = S.snapshot(st)
        lo = max(0, ingested - 2000)
        st, window = B.range_query_all(st, lo, ingested - 1, int(snap))
        # consistency check: the scan contains EXACTLY the visible prefix
        got_keys = [k for k, _ in window]
        assert got_keys == list(range(lo, ingested)), "torn read!"
        hist = np.bincount([v for _, v in window], minlength=100)
        st = S.release(st, snap)
        if epoch % 5 == 4:
            print(f"epoch {epoch+1:2d}: ingested={ingested:6d} "
                  f"window={len(window)} top-sensor={int(hist.argmax())} "
                  f"versions={int(st.n_vers)}")

        # retention: retire events older than 5 epochs, then GC
        if epoch % 5 == 4 and ingested > 5 * 512:
            horizon = ingested - 5 * 512
            old = np.arange(max(0, horizon - 512), horizon, dtype=np.int32)
            st, _ = B.apply_updates(
                st, old, np.full(len(old), S.TOMBSTONE, np.int32))
            st, n_live = S.compact(st)
            print(f"          GC: {int(n_live)} live events, "
                  f"versions={int(st.n_vers)}")

    print("all scans linearizable; done.")


if __name__ == "__main__":
    main()
