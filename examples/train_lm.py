"""End-to-end training driver: a ~100M-param llama-style LM, streaming
data from the Uruv sample store, MVCC checkpoints, straggler monitoring.

Full run (a few hundred steps, ~100M params — sized for a real box):
  PYTHONPATH=src python examples/train_lm.py --steps 300

CPU-friendly demo (reduced width, same code path; used by CI):
  PYTHONPATH=src python examples/train_lm.py --demo
"""

import argparse
import dataclasses

import numpy as np

from repro.api import KEY_DOMAIN_HI, UruvConfig
from repro.config import get_arch
from repro.data.pipeline import StreamingSampleStore
from repro.train.loop import TrainLoopConfig, train


def hundred_m_config():
    """llama3.2 family scaled to ~100M non-embedding params:
    12L x d768 x ff3072, 12 heads (GQA 4), 32k vocab."""
    cfg = get_arch("llama3_2_1b")
    return dataclasses.replace(
        cfg, n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
        head_dim=64, d_ff=3072, vocab=32000,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--demo", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    if args.demo:
        cfg = get_arch("llama3_2_1b").reduced()
        loop = TrainLoopConfig(batch_size=4, seq_len=64, total_steps=40,
                               log_every=10, ckpt_every=20,
                               ckpt_dir=args.ckpt_dir)
    else:
        cfg = hundred_m_config()
        loop = TrainLoopConfig(batch_size=args.batch, seq_len=args.seq,
                               total_steps=args.steps, log_every=10,
                               ckpt_every=50, ckpt_dir=args.ckpt_dir)

    # the data pipeline's streaming sample store (a repro.api.Uruv client)
    # ingests while we train; verify the primed epoch through the client's
    # snapshot + range surface
    n_prime = 1024 if args.demo else 4096
    store = StreamingSampleStore(
        UruvConfig(leaf_cap=64, max_leaves=512, max_versions=1 << 15)
        if args.demo else None
    )
    for i in range(0, n_prime, 128):       # fixed-width ingest batches
        ids = np.arange(i, i + 128, dtype=np.int32)
        store.ingest(ids, ids)
    with store.client.snapshot() as snap:
        primed = len(store.client.range(0, KEY_DOMAIN_HI, snap))
    print(f"sample store primed with {primed} samples "
          f"(clock={store.client.ts})")

    from repro.launch.roofline import model_params
    N, _ = model_params(cfg)
    print(f"training {cfg.name}: {N/1e6:.1f}M non-embedding params, "
          f"{loop.total_steps} steps @ batch {loop.batch_size} x "
          f"seq {loop.seq_len}")
    out = train(cfg, loop)
    print(f"loss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f} | "
          f"{out['steps_per_s']:.2f} steps/s | "
          f"stragglers {len(out['straggler_events'])}")
    assert out["losses"][-1] < out["losses"][0]


if __name__ == "__main__":
    main()
