"""Quickstart: the Uruv ADT in five minutes — through the one front door.

  PYTHONPATH=src python examples/quickstart.py

Covers the paper's full ADT via `repro.api`: wait-free batched
INSERT/DELETE/SEARCH, a typed mixed-op plan (`OpBatch`) applied in one
device pass, and a linearizable RANGEQUERY that is immune to concurrent
updates — plus the version tracker + compaction (GC).
"""

import numpy as np

from repro.api import OpBatch, Uruv, UruvConfig


def main():
    db = Uruv(UruvConfig(leaf_cap=32, max_leaves=1024, max_versions=1 << 16))

    # INSERT: fixed-width announce batches (the production ingest shape —
    # one wait-free combining pass each; fixed widths also mean the jitted
    # pass compiles once and is reused for every batch)
    keys = np.arange(0, 4_000, 2, dtype=np.int32)        # even keys
    for i in range(0, len(keys), 64):
        db.insert(keys[i:i+64], keys[i:i+64] * 10)
    print(f"inserted {len(keys)} keys -> {int(db.store.n_leaves)} leaves, "
          f"clock={db.ts}, device passes={db.stats['device_passes']}")

    # SEARCH: read-only probe at the current clock
    q = np.array([0, 2, 3, 3998], np.int32)
    print("lookup", dict(zip(q.tolist(), db.lookup(q).tolist())))

    # RANGEQUERY with snapshot isolation: register a snapshot, overwrite,
    # and re-read — the registered view never moves (released on exit)
    with db.snapshot() as snap:
        db.insert(keys[:50], keys[:50])                  # overwrite values
        old_view = db.range(0, 100, snap)
        new_view = db.range(0, 100)
        print("snapshot view :", old_view[:5], "(values * 10 — pre-overwrite)")
        print("latest view   :", new_view[:5], "(overwritten)")

    # One typed plan = one linearized announce array (one device pass per
    # CRUD segment): searches see earlier in-batch ops, the range op counts
    # live keys at its own announce timestamp
    res = db.apply(OpBatch.concat(
        OpBatch.searches([2, 3]),
        OpBatch.deletes([2]),
        OpBatch.ranges([0], [10]),
        OpBatch.inserts([3], [33]),
    ))
    print("plan values   :", res.values.tolist(),
          "| range page:", res.pages()[0])

    # DELETE writes tombstone versions; compact() reclaims them once no
    # active snapshot can see them (the paper's version tracker, App. E)
    for i in range(0, 1000, 64):
        db.delete(keys[i:i+64])
    print(f"versions before GC: {int(db.store.n_vers)}")
    n_live = db.compact()
    print(f"versions after  GC: {int(db.store.n_vers)} ({n_live} live keys)")


if __name__ == "__main__":
    main()
