"""Quickstart: the Uruv ADT in five minutes.

  PYTHONPATH=src python examples/quickstart.py

Covers the paper's full ADT — wait-free batched INSERT/DELETE/SEARCH and a
linearizable RANGEQUERY that is immune to concurrent updates — plus the
version tracker + compaction (GC).
"""

import numpy as np
import jax.numpy as jnp

from repro.core import batch as B
from repro.core import store as S
from repro.core.ref import NOT_FOUND, TOMBSTONE


def main():
    st = S.create(S.UruvConfig(leaf_cap=32, max_leaves=4096,
                               max_versions=1 << 18))

    # INSERT: one wait-free combining pass applies the whole announce array
    keys = np.arange(0, 10_000, 2, dtype=np.int32)       # even keys
    st, _ = B.apply_updates(st, keys, keys * 10)
    print(f"inserted {len(keys)} keys -> {int(st.n_leaves)} leaves, "
          f"clock={int(st.ts)}")

    # SEARCH (batched)
    q = np.array([0, 2, 3, 9998], np.int32)
    vals = S.bulk_lookup(st, jnp.asarray(q), jnp.asarray(int(st.ts), jnp.int32))
    print("search", dict(zip(q.tolist(), np.asarray(vals).tolist())))

    # RANGEQUERY with snapshot isolation: take a snapshot, then overwrite
    st, snap = S.snapshot(st)
    st, _ = B.apply_updates(st, keys[:50], keys[:50])    # overwrite values
    st, old_view = B.range_query_all(st, 0, 100, int(snap))
    st, new_view = B.range_query_all(st, 0, 100, None)
    print("snapshot view :", old_view[:5], "(values * 10 — pre-overwrite)")
    print("latest view   :", new_view[:5], "(overwritten)")

    # DELETE writes tombstone versions; compact() reclaims them once no
    # active snapshot can see them (the paper's version tracker, App. E)
    st, _ = B.apply_updates(
        st, keys[:1000], np.full(1000, TOMBSTONE, np.int32))
    print(f"versions before GC: {int(st.n_vers)}")
    st = S.release(st, snap)
    st, n_live = S.compact(st)
    print(f"versions after  GC: {int(st.n_vers)} ({int(n_live)} live keys)")


if __name__ == "__main__":
    main()
