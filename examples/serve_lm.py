"""Serving example: continuous batching + Uruv prefix cache.

Trains a tiny LM briefly (so generations are non-degenerate), then serves
a burst of requests sharing a common prompt prefix — the second wave hits
the Uruv prefix table (a `repro.api.Uruv` client inside the engine) and
skips recomputation.

  PYTHONPATH=src python examples/serve_lm.py
"""

import time

import numpy as np

from repro.api import KEY_DOMAIN_HI
from repro.config import get_arch
from repro.serve.engine import Engine, Request
from repro.train.loop import TrainLoopConfig, train


def main():
    cfg = get_arch("llama3_2_1b").reduced()
    out = train(cfg, TrainLoopConfig(batch_size=4, seq_len=64,
                                     total_steps=20, log_every=10))
    params = out["state"].params

    eng = Engine(cfg, params, n_slots=4, max_len=64)
    rng = np.random.default_rng(0)
    system_prompt = rng.integers(0, cfg.vocab, 8).tolist()

    def burst(tag, n):
        reqs = [
            Request(rid=i,
                    prompt=system_prompt + rng.integers(
                        0, cfg.vocab, 2 + i % 3).tolist(),
                    max_new=8)
            for i in range(n)
        ]
        t0 = time.time()
        eng.run(reqs)
        dt = time.time() - t0
        toks = sum(len(r.out) for r in reqs)
        reused = sum(r.prefix_reused for r in reqs)
        print(f"{tag}: {toks} tokens in {dt:.2f}s "
              f"({toks/dt:.1f} tok/s), prefix tokens reused: {reused}")
        return reqs

    burst("wave 1 (cold)", 4)
    burst("wave 2 (prefix-cached)", 4)
    # the engine's prefix table IS a repro.api.Uruv client: read it through
    # the same front door — a registered snapshot + one batched range scan
    with eng.table.snapshot() as snap:
        entries = eng.table.range(0, KEY_DOMAIN_HI, snap)
    print(f"prefix-table entries: {len(entries)} "
          f"(table device passes: {eng.table.stats['device_passes']})")


if __name__ == "__main__":
    main()
