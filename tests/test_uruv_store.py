"""UruvStore vs the sequential oracle: deterministic scenarios."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import batch as B
from repro.core import store as S
from repro.core.ref import (
    KEY_MAX, NOT_FOUND, TOMBSTONE, OP_DELETE, OP_INSERT, OP_SEARCH, RefStore,
)

CFG = S.UruvConfig(leaf_cap=8, max_leaves=256, max_versions=8192, max_chain=16)


def fresh():
    return S.create(CFG), RefStore()


def apply_ref_updates(ref, keys, vals):
    return ref.apply_batch([(OP_INSERT, int(k), int(v))
                            for k, v in zip(keys, vals)])


def test_empty_lookup():
    st, _ = fresh()
    out = S.bulk_lookup(st, jnp.asarray([1, 5, KEY_MAX], jnp.int32),
                        jnp.asarray(100, jnp.int32))
    assert np.asarray(out).tolist() == [NOT_FOUND] * 3


def test_insert_search_delete_roundtrip():
    st, ref = fresh()
    keys = np.array([10, 20, 30, 20], np.int32)     # dup key in one batch
    vals = np.array([1, 2, 3, 4], np.int32)
    st, prev = B.apply_updates(st, keys, vals)
    rprev = apply_ref_updates(ref, keys, vals)
    assert prev.tolist() == rprev
    assert S.live_items(st) == [(10, 1), (20, 4), (30, 3)]
    # delete 20
    st, prev = B.apply_updates(
        st, np.array([20], np.int32), np.array([TOMBSTONE], np.int32))
    assert prev.tolist() == [4]
    ref.apply_batch([(OP_DELETE, 20, 0)])
    assert S.live_items(st) == ref.live_items() == [(10, 1), (30, 3)]
    S.check_invariants(st)


def test_randomized_vs_oracle():
    rng = np.random.default_rng(0)
    st, ref = fresh()
    for it in range(25):
        keys = rng.integers(0, 150, 16).astype(np.int32)
        vals = rng.integers(0, 1000, 16).astype(np.int32)
        dels = rng.random(16) < 0.25
        vals = np.where(dels, TOMBSTONE, vals).astype(np.int32)
        st, prev = B.apply_updates(st, keys, vals)
        rprev = apply_ref_updates(ref, keys, vals)
        np.testing.assert_array_equal(prev, rprev, err_msg=f"iter {it}")
        S.check_invariants(st)
        assert S.live_items(st) == ref.live_items()
    # clock agreement -> identical snapshot semantics
    assert int(st.ts) == ref.ts


def test_mixed_batch_linearization():
    rng = np.random.default_rng(1)
    st, ref = fresh()
    keys = rng.integers(0, 60, 32).astype(np.int32)
    vals = rng.integers(0, 100, 32).astype(np.int32)
    st, _ = B.apply_updates(st, keys, vals)
    apply_ref_updates(ref, keys, vals)
    ops = []
    for i in range(24):
        r = rng.random()
        k = int(rng.integers(0, 70))
        if r < 0.4:
            ops.append((OP_INSERT, k, int(rng.integers(0, 100))))
        elif r < 0.6:
            ops.append((OP_DELETE, k, 0))
        else:
            ops.append((OP_SEARCH, k, 0))
    st, res = B.apply_batch(st, ops)
    assert res == ref.apply_batch(ops)


def test_snapshot_isolation_and_range():
    rng = np.random.default_rng(2)
    st, ref = fresh()
    keys = rng.integers(0, 100, 32).astype(np.int32)
    vals = rng.integers(0, 100, 32).astype(np.int32)
    st, _ = B.apply_updates(st, keys, vals)
    apply_ref_updates(ref, keys, vals)
    st, snap = S.snapshot(st)
    rsnap = ref.snapshot()
    assert int(snap) == rsnap
    # overwrite everything after the snapshot
    st, _ = B.apply_updates(st, keys, (vals + 1000).astype(np.int32))
    apply_ref_updates(ref, keys, (vals + 1000).astype(np.int32))
    st, got = B.range_query_all(st, 0, 99, int(snap), max_scan_leaves=4,
                                max_results=16)
    assert got == ref.range_query(0, 99, rsnap)   # sees pre-overwrite values
    st, got_now = B.range_query_all(st, 0, 99, None)
    assert got_now == ref.range_query(0, 99, ref.ts)


def test_range_pagination_truncation():
    st, ref = fresh()
    keys = np.arange(0, 200, dtype=np.int32)
    vals = keys * 2
    for i in range(0, 200, 8):
        st, _ = B.apply_updates(st, keys[i:i+8], vals[i:i+8])
        apply_ref_updates(ref, keys[i:i+8], vals[i:i+8])
    st, got = B.range_query_all(st, 5, 180, None, max_scan_leaves=2,
                                max_results=8)
    assert got == ref.range_query(5, 180, ref.ts)


def test_compact_preserves_snapshots_and_gc():
    rng = np.random.default_rng(3)
    st, ref = fresh()
    keys = rng.integers(0, 50, 32).astype(np.int32)
    vals = rng.integers(0, 100, 32).astype(np.int32)
    st, _ = B.apply_updates(st, keys, vals)
    apply_ref_updates(ref, keys, vals)
    st, snap = S.snapshot(st)
    rsnap = ref.snapshot()
    want_old = ref.range_query(0, 60, rsnap)
    st, _ = B.apply_updates(st, keys, (vals + 7).astype(np.int32))
    apply_ref_updates(ref, keys, (vals + 7).astype(np.int32))

    vers_before = int(st.n_vers)
    st, _ = S.compact(st)          # snapshot active: old versions retained
    S.check_invariants(st)
    st, got = B.range_query_all(st, 0, 60, int(snap))
    assert got == want_old
    st = S.release(st, snap)
    ref.release(rsnap)
    st, _ = S.compact(st)          # now reclaim
    S.check_invariants(st)
    assert int(st.n_vers) < vers_before
    assert S.live_items(st) == ref.live_items()


def test_slow_path_on_leaf_concentration():
    """> leaf_cap new keys into one leaf must abort + retry in rounds."""
    st, ref = fresh()
    keys = np.arange(100, 132, dtype=np.int32)   # 32 new keys, 1 leaf, L=8
    vals = keys.copy()
    st2, _, ok = S.bulk_update(st, jnp.asarray(keys), jnp.asarray(vals))
    assert not bool(ok)
    assert int(st2.oflow) & S.OFLOW_LEAFBATCH
    # combining layer resolves it
    st, prev = B.apply_updates(st, keys, vals)
    apply_ref_updates(ref, keys, vals)
    assert S.live_items(st) == ref.live_items()
    S.check_invariants(st)


def test_capacity_error_when_full():
    """The fixed-footprint contract: with the self-sizing lifecycle
    disabled (policy=None at the combining layer), overflowing the pools
    still raises CapacityError — now with diagnostics attached.  The
    DEFAULT repro.api policy grows instead (tests/test_lifecycle.py)."""
    tiny = S.UruvConfig(leaf_cap=4, max_leaves=8, max_versions=64,
                        max_chain=8)
    st = S.create(tiny)
    keys = np.arange(0, 64, dtype=np.int32)
    with pytest.raises(B.CapacityError) as ei:
        for i in range(0, 64, 8):
            codes = np.full(8, OP_INSERT, np.int32)
            st, _ = B._apply_rounds(st, codes, keys[i:i+8], keys[i:i+8],
                                    None, None, policy=None)
    assert ei.value.oflow or ei.value.max_versions == 64


def test_version_tracker_min_active():
    st, _ = fresh()
    st, s1 = S.snapshot(st)
    st, _ = B.apply_updates(st, np.array([1], np.int32),
                            np.array([1], np.int32))
    st, s2 = S.snapshot(st)
    assert int(S.min_active_ts(st)) == int(s1)
    st = S.release(st, s1)
    assert int(S.min_active_ts(st)) == int(s2)
    st = S.release(st, s2)
    assert int(S.min_active_ts(st)) == int(st.ts)


def test_paper_leaf_protocol_fields():
    """Split marks the old leaf frozen and forwards via newNext (paper 3.1)."""
    st, _ = fresh()
    keys = np.arange(0, 9, dtype=np.int32)       # overflows L=8 -> split
    st, _ = B.apply_updates(st, keys[:8], keys[:8])
    assert int(st.n_leaves) == 1
    old_leaf = int(S.directory(st)[1][0])
    st, _ = B.apply_updates(st, keys[8:], keys[8:])
    assert int(st.n_leaves) == 2
    assert bool(st.leaf_frozen[old_leaf])
    fwd = int(st.leaf_newnext[old_leaf])
    assert fwd == int(S.directory(st)[1][0])     # newNext -> replacement left
    # leaf chain matches directory order and timestamps were stamped
    S.check_invariants(st)
    assert int(st.leaf_ts[fwd]) > 0
