"""bulk_apply — the fused mixed-op pass (DESIGN.md Sec 3).

Linearization equivalence against the sequential oracle, per-op timestamp
plumbing, fast-path single-device-pass guarantee, backend dispatch, and
sharded-vs-single-device equivalence (results AND version timestamps).
"""

import subprocess
import sys
from pathlib import Path

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import backend as BK
from repro.core import batch as B
from repro.core import store as S
from repro.core.ref import (
    KEY_MAX, NOT_FOUND, TOMBSTONE, OP_DELETE, OP_INSERT, OP_NOP, OP_SEARCH,
    RefStore,
)

CFG = S.UruvConfig(leaf_cap=8, max_leaves=512, max_versions=1 << 14,
                   max_chain=16)


def random_ops(rng, n, key_hi=70):
    codes = rng.choice(
        [OP_INSERT, OP_INSERT, OP_DELETE, OP_SEARCH, OP_SEARCH, OP_NOP], n
    ).astype(np.int32)
    keys = rng.integers(0, key_hi, n).astype(np.int32)
    vals = rng.integers(0, 1000, n).astype(np.int32)
    return codes, keys, vals


def test_mixed_announce_vs_oracle_deterministic():
    """Interleaved SEARCH/INSERT/DELETE with duplicate keys, announce order."""
    st = S.create(CFG)
    ref = RefStore()
    ops = [
        (OP_SEARCH, 5, 0),          # absent
        (OP_INSERT, 5, 10),
        (OP_SEARCH, 5, 0),          # sees 10 (in-batch predecessor)
        (OP_INSERT, 5, 20),
        (OP_DELETE, 5, 0),
        (OP_SEARCH, 5, 0),          # sees tombstone -> NOT_FOUND
        (OP_INSERT, 7, 70),
        (OP_SEARCH, 7, 0),
        (OP_NOP, 99, 1),
        (OP_INSERT, 5, 30),
        (OP_SEARCH, 5, 0),          # sees 30
    ]
    st, res = B.apply_batch(st, ops)
    assert res == ref.apply_batch(ops)
    assert int(st.ts) == ref.ts
    S.check_invariants(st)
    assert S.live_items(st) == ref.live_items()


def test_search_past_long_in_batch_chain():
    """A search after > max_chain same-key updates is exact (predecessor
    short-circuit, not a bounded chain walk)."""
    st = S.create(CFG)
    ref = RefStore()
    ops = [(OP_INSERT, 3, i) for i in range(CFG.max_chain + 10)]
    ops.append((OP_SEARCH, 3, 0))
    st, res = B.apply_batch(st, ops)
    assert res == ref.apply_batch(ops)
    assert res[-1] == CFG.max_chain + 9


@pytest.mark.parametrize("width", [1, 2, 3, 7, 16, 33, 64])
def test_width_sweep_vs_oracle(width):
    rng = np.random.default_rng(width)
    st = S.create(CFG)
    ref = RefStore()
    for it in range(6):
        codes, keys, vals = random_ops(rng, width)
        ops = [(int(c), int(k), int(v)) for c, k, v in zip(codes, keys, vals)]
        st, res = B.apply_batch(st, ops)
        assert res == ref.apply_batch(ops), (width, it)
        assert int(st.ts) == ref.ts
    S.check_invariants(st)
    assert S.live_items(st) == ref.live_items()


def test_explicit_op_ts_subset_application():
    """Applying a routed subset with explicit global timestamps equals the
    full-array application (the sharded-store contract)."""
    full_st = S.create(CFG)
    sub_st = S.create(CFG)
    rng = np.random.default_rng(0)
    codes, keys, vals = random_ops(rng, 24, key_hi=40)
    full_st, full_res, ok = S.bulk_apply(full_st, codes, keys, vals)
    assert bool(ok)
    # split by key parity into two "shards", apply each subset with its ops'
    # original announce positions as op_ts
    n = len(keys)
    for parity in (0, 1):
        mask = (keys % 2) == parity
        c = np.where(mask, codes, OP_NOP).astype(np.int32)
        k = np.where(mask, keys, KEY_MAX).astype(np.int32)
        sub_st, sub_res, ok = S.bulk_apply(
            sub_st, c, k, vals,
            op_ts=jnp.arange(n, dtype=jnp.int32),
            next_ts=jnp.asarray(n if parity else 0, jnp.int32),
        )
        assert bool(ok)
        want = np.where(mask, np.asarray(full_res), NOT_FOUND)
        np.testing.assert_array_equal(np.asarray(sub_res), want)
    assert int(sub_st.ts) == int(full_st.ts)
    assert S.live_items(sub_st) == S.live_items(full_st)
    # version timestamps agree key-by-key
    for key, _ in S.live_items(full_st):
        q = jnp.asarray([key], jnp.int32)
        _, _, _, _, _, vh_a = S._locate(full_st, q)
        _, _, _, _, _, vh_b = S._locate(sub_st, q)
        assert int(full_st.ver_ts[int(vh_a[0])]) == int(sub_st.ver_ts[int(vh_b[0])])


def test_fast_path_is_one_device_pass(monkeypatch):
    """apply_batch on a mixed announce array must issue exactly one
    bulk_apply call and NO separate bulk_lookup call on the fast path."""
    st = S.create(CFG)
    rng = np.random.default_rng(1)
    keys = rng.integers(0, 40, 16).astype(np.int32)
    st, _ = B.apply_updates(st, keys, keys)

    calls = {"apply": 0, "lookup": 0}
    orig_apply = S.bulk_apply
    monkeypatch.setattr(
        S, "bulk_apply",
        lambda *a, **kw: (calls.__setitem__("apply", calls["apply"] + 1),
                          orig_apply(*a, **kw))[1],
    )
    monkeypatch.setattr(
        S, "bulk_lookup",
        lambda *a, **kw: (_ for _ in ()).throw(
            AssertionError("separate bulk_lookup on the fast path")),
    )
    ops = [(OP_SEARCH, int(keys[0]), 0), (OP_INSERT, int(keys[1]), 9),
           (OP_DELETE, int(keys[2]), 0), (OP_SEARCH, 999, 0)]
    st, res = B.apply_batch(st, ops)
    assert calls["apply"] == 1
    assert res[3] == NOT_FOUND


def test_bulk_update_lookup_are_thin_wrappers():
    """Wrapper equivalence: bulk_update == bulk_apply with derived codes."""
    rng = np.random.default_rng(2)
    keys = rng.integers(0, 50, 20).astype(np.int32)
    vals = rng.integers(0, 100, 20).astype(np.int32)
    vals[::5] = TOMBSTONE
    keys[3] = KEY_MAX
    st1 = S.create(CFG)
    st2 = S.create(CFG)
    st1, prev1, ok1 = S.bulk_update(st1, jnp.asarray(keys), jnp.asarray(vals))
    codes = np.where(
        keys >= KEY_MAX, OP_NOP,
        np.where(vals == TOMBSTONE, OP_DELETE, OP_INSERT),
    ).astype(np.int32)
    st2, prev2, ok2 = S.bulk_apply(st2, codes, keys, vals)
    assert bool(ok1) == bool(ok2)
    np.testing.assert_array_equal(np.asarray(prev1), np.asarray(prev2))
    assert S.live_items(st1) == S.live_items(st2)
    got = S.bulk_lookup(st1, jnp.asarray(keys[:4]),
                        jnp.asarray(int(st1.ts), jnp.int32))
    _, want, _ = S.bulk_apply(
        st2, np.full(4, OP_SEARCH, np.int32), keys[:4], np.zeros(4, np.int32),
        op_ts=jnp.full((4,), int(st2.ts), jnp.int32),
        next_ts=st2.ts,
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("backend", [BK.XLA, BK.PALLAS_INTERPRET])
def test_backend_dispatch_equivalence(backend):
    """The Pallas kernels (interpret mode off-TPU) and the XLA oracle give
    identical bulk_apply results."""
    rng = np.random.default_rng(3)
    st = S.create(CFG)
    ref = RefStore()
    for it in range(3):
        codes, keys, vals = random_ops(rng, 16, key_hi=30)
        ops = [(int(c), int(k), int(v)) for c, k, v in zip(codes, keys, vals)]
        st2, res, ok = S.bulk_apply(st, codes, keys, vals, backend=backend)
        rres = ref.apply_batch(ops)
        if not bool(ok):
            # keep oracle in sync by replaying via the slow path
            st, bres = B.apply_batch(st, ops)
            assert bres == rres
            continue
        st = st2
        assert np.asarray(res).tolist() == rres, (backend, it)


def test_backend_resolution_env_and_override(monkeypatch):
    monkeypatch.setenv(BK.ENV_VAR, BK.PALLAS_INTERPRET)
    assert BK.get_backend() == BK.PALLAS_INTERPRET
    BK.set_backend(BK.XLA)
    try:
        assert BK.get_backend() == BK.XLA
    finally:
        BK.set_backend(None)
    monkeypatch.delenv(BK.ENV_VAR)
    assert BK.get_backend() in BK.BACKENDS
    with pytest.raises(ValueError):
        BK.set_backend("tpu9000")


SHARDED_EQUIV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "src")
import numpy as np, jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core import store as S, sharded as SH, batch as B
from repro.core.ref import RefStore, OP_INSERT, OP_DELETE, OP_SEARCH

mesh = make_mesh((4,), ("data",))
base = S.UruvConfig(leaf_cap=8, max_leaves=128, max_versions=2048)
cfg = SH.ShardedConfig(base=base, key_lo=0, key_hi=400)
st = SH.create(cfg, mesh)
apply_fn = SH.make_apply(cfg, mesh)
routed_fn = SH.make_routed_apply(cfg, mesh, route_factor=2)
single = S.create(base)
ref = RefStore()
rng = np.random.default_rng(7)
for it in range(6):
    G = 16
    codes = rng.choice([OP_INSERT, OP_INSERT, OP_DELETE, OP_SEARCH], G).astype(np.int32)
    keys = rng.integers(0, 400, G).astype(np.int32)
    vals = rng.integers(0, 1000, G).astype(np.int32)
    st, res = SH.sharded_apply_batch(st, codes, keys, vals,
                                     apply_fn=apply_fn, routed_fn=routed_fn)
    ops = [(int(c), int(k), int(v)) for c, k, v in zip(codes, keys, vals)]
    single, sres = B.apply_batch(single, ops)
    rres = ref.apply_batch(ops)
    assert res.tolist() == rres == sres, (it, res.tolist(), rres)
    assert SH.global_ts(st) == int(single.ts) == ref.ts
assert np.unique(np.asarray(st.ts)).size == 1   # replicated clock agrees
# per-key version timestamps identical between sharded and single-device
sh = jax.device_get(st)
checked = 0
for shard in range(4):
    ents = np.asarray(sh.index.leaf_ent[shard])
    for lid in np.nonzero(ents >= 0)[0]:
        lid = int(lid)
        for j in range(int(sh.leaf_count[shard][lid])):
            k = int(sh.leaf_keys[shard][lid, j])
            vh = int(sh.leaf_vhead[shard][lid, j])
            _, _, _, _, ex, vh1 = S._locate(single, jnp.asarray([k], jnp.int32))
            assert bool(ex[0]), k
            assert int(sh.ver_ts[shard][vh]) == int(single.ver_ts[int(vh1[0])]), k
            checked += 1
assert checked > 0
print("SHARDED_EQUIV_OK")
"""


def test_sharded_bulk_apply_matches_single_device():
    r = subprocess.run(
        [sys.executable, "-c", SHARDED_EQUIV_SCRIPT],
        capture_output=True, text=True, timeout=900,
        cwd=str(Path(__file__).resolve().parents[1]),
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "SHARDED_EQUIV_OK" in r.stdout
