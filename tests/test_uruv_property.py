"""Property-based tests (hypothesis): the JAX store is indistinguishable
from the sequential oracle under arbitrary announce histories, and
snapshots are linearizable across compaction."""

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)",
)
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import batch as B
from repro.core import store as S
from repro.core.ref import (
    NOT_FOUND, TOMBSTONE, OP_DELETE, OP_INSERT, OP_SEARCH, RefStore,
)

CFG = S.UruvConfig(leaf_cap=8, max_leaves=512, max_versions=1 << 14,
                   max_chain=32)

op_st = st.tuples(
    st.sampled_from([OP_INSERT, OP_INSERT, OP_DELETE, OP_SEARCH]),
    st.integers(0, 80),
    st.integers(0, 1000),
)
batch_st = st.lists(op_st, min_size=1, max_size=24)
history_st = st.lists(batch_st, min_size=1, max_size=6)

SET = settings(
    max_examples=40, deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)


@given(history_st)
@SET
def test_history_equivalence(history):
    store = S.create(CFG)
    ref = RefStore()
    for ops in history:
        store, res = B.apply_batch(store, ops)
        assert res == ref.apply_batch(ops)
    assert S.live_items(store) == ref.live_items()
    S.check_invariants(store)
    assert int(store.ts) == ref.ts


@given(history_st, st.integers(0, 5), st.integers(0, 80), st.integers(0, 80))
@SET
def test_snapshot_linearizability_across_compaction(history, snap_after,
                                                    k1, k2):
    """A snapshot taken mid-history reads the same range result before and
    after arbitrary later updates AND a compaction (paper Sec 5.1 + App E)."""
    if k2 < k1:
        k1, k2 = k2, k1
    store = S.create(CFG)
    ref = RefStore()
    snap = rsnap = None
    want = None
    for i, ops in enumerate(history):
        if i == min(snap_after, len(history) - 1) and snap is None:
            store, snap = S.snapshot(store)
            rsnap = ref.snapshot()
            assert int(snap) == rsnap
            want = ref.range_query(k1, k2, rsnap)
            store, got = B.range_query_all(store, k1, k2, int(snap))
            assert got == want
        store, _ = B.apply_batch(store, ops)
        ref.apply_batch(ops)
    if snap is not None:
        store, got = B.range_query_all(store, k1, k2, int(snap))
        assert got == want
        store, _ = S.compact(store)
        store, got = B.range_query_all(store, k1, k2, int(snap))
        assert got == want, "compaction must not disturb active snapshots"
        S.check_invariants(store)


@given(st.lists(st.integers(0, 100), min_size=1, max_size=40),
       st.integers(1, 16))
@SET
def test_round_splitting_invariance(keys, width):
    """Applying one announce array in arbitrary round widths (the slow path)
    yields the same store contents as the oracle's sequential application."""
    store = S.create(CFG)
    ref = RefStore()
    keys = np.array(keys, np.int32)
    vals = (keys * 3 + 1).astype(np.int32)
    for i in range(0, len(keys), width):
        store, _ = B.apply_updates(store, keys[i:i+width], vals[i:i+width])
    ref.apply_batch([(OP_INSERT, int(k), int(v))
                     for k, v in zip(keys, vals)])
    assert S.live_items(store) == ref.live_items()


@given(st.lists(st.tuples(st.integers(0, 40), st.integers(0, 100)),
                min_size=1, max_size=32))
@SET
def test_search_sees_latest_version(pairs):
    store = S.create(CFG)
    ref = RefStore()
    keys = np.array([k for k, _ in pairs], np.int32)
    vals = np.array([v for _, v in pairs], np.int32)
    store, _ = B.apply_updates(store, keys, vals)
    ref.apply_batch([(OP_INSERT, int(k), int(v))
                     for k, v in zip(keys, vals)])
    q = np.unique(keys)
    got = np.asarray(S.bulk_lookup(
        store, jnp.asarray(q), jnp.asarray(int(store.ts), jnp.int32)))
    want = [ref.search(int(k)) for k in q]
    assert got.tolist() == want
