"""Multi-level fat-node index battery (repro.core.index; DESIGN.md Sec 11).

Covers: packed build vs the flat-directory oracle, delta-vs-rebuild
equivalence under random structural churn, bottom-up node-split
propagation at small fanout, the OFLOW_INDEX atomic reject, reindex
defragmentation, growth tail-extension with depth increase, and the
index counters.  The full-store invariant checker (per-level sortedness,
child coverage, spine/reverse-map coherence, leaf_next == leftmost-
descent order) runs after every structural step.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import batch as B
from repro.core import index as I
from repro.core import lifecycle as LC
from repro.core import store as S
from repro.core.ref import (
    KEY_MAX, OP_DELETE, OP_INSERT, OP_SEARCH, RefStore,
)

RNG = np.random.default_rng(42)


def _cfg(**kw):
    base = dict(leaf_cap=8, max_leaves=256, max_versions=1 << 13,
                tracker_cap=16, max_chain=16, index_fanout=4)
    base.update(kw)
    return S.UruvConfig(**base)


def _ingest(st, ref, rng, rounds, width=32, universe=4000, p_ins=0.6,
            p_del=0.25, check_every=1):
    for it in range(rounds):
        r = rng.random(width)
        codes = np.where(r < p_ins, OP_INSERT,
                         np.where(r < p_ins + p_del, OP_DELETE,
                                  OP_SEARCH)).astype(np.int32)
        keys = rng.integers(0, universe, width).astype(np.int32)
        vals = (keys % 97 + 1).astype(np.int32)
        ops = [(int(c), int(k), int(v))
               for c, k, v in zip(codes, keys, vals)]
        st, res = B.apply_batch(st, ops)
        assert res == ref.apply_batch(ops)
        if (it + 1) % check_every == 0:
            S.check_invariants(st)
    return st


# ---------------------------------------------------------------------------
# build vs flat oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_sep,fanout", [(1, 4), (3, 4), (40, 4),
                                          (200, 8), (250, 16)])
def test_build_matches_flat_oracle(n_sep, fanout):
    ML = 256
    seps = np.sort(RNG.choice(100_000, n_sep, replace=False)).astype(np.int32)
    seps[0] = I.KEY_MIN
    leaves = RNG.permutation(ML)[:n_sep].astype(np.int32)
    pad_k = np.full(ML, KEY_MAX, np.int32)
    pad_k[:n_sep] = seps
    pad_l = np.full(ML, -1, np.int32)
    pad_l[:n_sep] = leaves
    idx = I.build(I.index_config(ML, fanout), ML, pad_k, pad_l,
                  jnp.asarray(n_sep, jnp.int32))
    I.check_index(idx, n_sep)

    q = np.concatenate([
        RNG.integers(-1000, 101_000, 256).astype(np.int32),
        seps, seps + 1, seps - 1,
        np.array([I.KEY_MIN, I.KEY_MIN + 1, KEY_MAX - 1], np.int32),
    ])
    # descend == flat searchsorted rank
    want = np.maximum(
        np.searchsorted(seps, q, side="right").astype(np.int32) - 1, 0)
    bnode, bslot, leaf = I.descend(idx, jnp.asarray(q))
    got = np.asarray(I.leaf_ordinal(idx, bnode, bslot))
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(np.asarray(leaf), leaves[want])
    np.testing.assert_array_equal(np.asarray(I.rank_right(idx, jnp.asarray(q))),
                                  want + 1)
    # select: leaf_at / sep_at over every live ordinal
    p = jnp.arange(n_sep, dtype=jnp.int32)
    np.testing.assert_array_equal(np.asarray(I.leaf_at(idx, p)), leaves)
    np.testing.assert_array_equal(np.asarray(I.sep_at(idx, p)), seps)
    # flat view round-trips
    dk, dl = I.directory(idx, n_sep)
    np.testing.assert_array_equal(dk, seps)
    np.testing.assert_array_equal(dl, leaves)


def test_depth1_build_packs_into_root():
    """ML <= F yields a depth-1 index whose root IS the bottom level:
    build must pack EVERY separator into node 0 — descent never leaves
    it (regression: packing at pack_fill spilled entries past 3F/4 into
    an unreachable second node)."""
    ML = F = 16
    n_sep = 16                         # > pack_fill(16) == 12
    cfg = I.index_config(ML, F)
    assert cfg.depth == 1
    seps = (np.arange(n_sep, dtype=np.int64) * 10).astype(np.int32)
    seps[0] = I.KEY_MIN
    leaves = np.arange(n_sep, dtype=np.int32)
    idx = I.build(cfg, ML, seps, leaves, jnp.asarray(n_sep, jnp.int32))
    I.check_index(idx, n_sep)
    q = np.concatenate([seps, seps + 1]).astype(np.int32)
    _, _, leaf = I.descend(idx, jnp.asarray(q))
    want = np.maximum(
        np.searchsorted(seps, q, side="right").astype(np.int32) - 1, 0)
    np.testing.assert_array_equal(np.asarray(leaf), leaves[want])
    # the same geometry end-to-end: a depth-1 store past 3F/4 leaves,
    # through compact (a fresh packed build) and a depth-deepening grow
    st = S.create(_cfg(max_leaves=16, index_fanout=16, leaf_cap=4))
    ref = RefStore()
    k = 0
    while int(st.n_leaves) <= 12:      # past pack_fill, inside the pool
        ops = [(OP_INSERT, k + i, k + i + 1) for i in range(4)]
        st, res = B.apply_batch(st, ops)
        assert res == ref.apply_batch(ops)
        k += 4
    assert st.index.cfg.depth == 1 and int(st.n_leaves) > 12
    S.check_invariants(st)
    st2, _ = S.compact(st)
    S.check_invariants(st2)
    assert S.live_items(st2) == ref.live_items()
    g = LC.grow(st, leaves=True)
    S.check_invariants(g)
    assert S.live_items(g) == ref.live_items()


# ---------------------------------------------------------------------------
# delta application == stop-the-world rebuild (the tentpole equivalence)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fanout", [4, 8])
def test_delta_matches_rebuild_under_churn(fanout):
    rng = np.random.default_rng(fanout)
    st = S.create(_cfg(index_fanout=fanout))
    ref = RefStore()
    for it in range(14):
        st = _ingest(st, ref, rng, 1, width=48)
        # the incrementally-maintained index must expose EXACTLY the flat
        # view a from-scratch repack would
        repacked = S.reindex(st)
        a = S.directory(st)
        b = S.directory(repacked)
        np.testing.assert_array_equal(a[0], b[0])
        np.testing.assert_array_equal(a[1], b[1])
        S.check_invariants(repacked)
    assert S.live_items(st) == ref.live_items()


def test_node_split_propagation_small_fanout():
    """fanout=4 forces a deep tree whose node splits cascade upward; the
    propagation counter observes them and invariants hold throughout."""
    rng = np.random.default_rng(7)
    st = S.create(_cfg(index_fanout=4, max_leaves=512,
                       max_versions=1 << 14))
    ref = RefStore()
    st = _ingest(st, ref, rng, 30, width=64, universe=50_000, p_del=0.1)
    assert st.index.cfg.depth >= 3
    assert int(st.index.stat_delta_passes) > 0
    assert int(st.index.stat_propagations) > 0, \
        "no node split ever propagated above the bottom level"
    assert S.live_items(st) == ref.live_items()


def test_version_only_batches_skip_the_index():
    """Overwrite/search-only batches must not touch the index at all —
    the light path's structural skip extends to the delta pass."""
    st = S.create(_cfg())
    keys = np.arange(0, 40, dtype=np.int32)
    st, _, ok = S.bulk_apply(st, np.full(40, OP_INSERT, np.int32), keys,
                             keys + 1)
    st, _ = B.apply_batch(st, [(OP_INSERT, int(k), int(k) + 1)
                               for k in keys])
    before = int(st.index.stat_delta_passes)
    st, _, ok = S.bulk_apply(
        st, np.full(40, OP_INSERT, np.int32), keys, keys + 2)  # overwrites
    assert bool(ok)
    assert int(st.index.stat_delta_passes) == before
    st, _, ok = S.bulk_apply(
        st, np.full(40, OP_SEARCH, np.int32), keys, keys)
    assert bool(ok)
    assert int(st.index.stat_delta_passes) == before


# ---------------------------------------------------------------------------
# overflow reject + reindex recovery
# ---------------------------------------------------------------------------

def test_split_delta_overflow_rejects_atomically():
    """More node splits than free pool slots -> oflow=True; the input
    index is untouched (functional reject)."""
    ML, F = 256, 4
    cfg = I.index_config(ML, F)
    n_sep = 250
    seps = np.arange(n_sep, dtype=np.int32) * 10
    seps[0] = I.KEY_MIN
    pad_k = np.full(ML, KEY_MAX, np.int32)
    pad_k[:n_sep] = seps
    pad_l = np.full(ML, -1, np.int32)
    pad_l[:n_sep] = np.arange(n_sep, dtype=np.int32)
    idx = I.build(cfg, ML, pad_k, pad_l, jnp.asarray(n_sep, jnp.int32))
    free = int(cfg.caps[0]) - int(np.asarray(idx.n_nodes0))
    # one insert into every live leaf's entry -> every bottom node gains
    # its cnt again -> (pack_fill=3 -> new_cnt=6 > F) every node splits
    P = n_sep
    valid = jnp.ones((P,), bool)
    gkey = jnp.asarray(seps)
    old_leaf = jnp.asarray(pad_l[:n_sep])
    left = jnp.arange(P, dtype=jnp.int32) + 1000
    right = jnp.arange(P, dtype=jnp.int32) + 5000
    rkey = jnp.asarray(seps + 5)
    new_idx, oflow = I.apply_split_delta(idx, valid, gkey, old_leaf, left,
                                         right, rkey)
    assert int(np.asarray(idx.n_nodes0)) > free, "test premise broken"
    assert bool(oflow), "expected node-pool overflow"
    # the ORIGINAL index is still intact (callers discard new_idx)
    I.check_index(idx, n_sep)


def test_fragmentation_reindex_packs():
    """Merge churn leaves underfull nodes behind; reindex repacks them to
    pack_fill and every result is unchanged."""
    rng = np.random.default_rng(3)
    st = S.create(_cfg(leaf_cap=8, index_fanout=4))
    ref = RefStore()
    st = _ingest(st, ref, rng, 10, width=48, universe=2000, p_ins=0.8,
                 p_del=0.05)
    # tombstone most keys, then merge leaves away
    live = [k for k, _ in ref.live_items()]
    dels = np.asarray(live[::2] + live[1::4], np.int32)
    for i in range(0, len(dels), 32):
        chunk = dels[i:i + 32]
        ops = [(OP_DELETE, int(k), 0) for k in chunk]
        st, res = B.apply_batch(st, ops)
        assert res == ref.apply_batch(ops)
    for p in range(6):
        st, _, _ = LC.maintain(st, 64, phase=p % 2)
        S.check_invariants(st)
    n_nodes_before = int(np.asarray(st.index.n_nodes0))
    packed = S.reindex(st)
    S.check_invariants(packed)
    assert int(np.asarray(packed.index.n_nodes0)) <= n_nodes_before
    assert S.live_items(packed) == ref.live_items()
    # reads at a historic snapshot are byte-identical across the repack
    snap = int(st.ts) - 5
    probe = jnp.arange(0, 2000, 3, dtype=jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(S.bulk_lookup(packed, probe, snap)),
        np.asarray(S.bulk_lookup(st, probe, snap)))


# ---------------------------------------------------------------------------
# growth
# ---------------------------------------------------------------------------

def test_grow_tail_extends_and_deepens():
    rng = np.random.default_rng(9)
    st = S.create(_cfg(max_leaves=64, index_fanout=4, leaf_cap=8))
    ref = RefStore()
    st = _ingest(st, ref, rng, 6, width=32, universe=1500)
    d0 = st.index.cfg.depth
    g = LC.grow(st, leaves=True)
    assert g.cfg.max_leaves == 128
    assert g.index.cfg.depth >= d0
    for l in range(d0):
        old = np.asarray(st.index.node_keys[l])
        new = np.asarray(g.index.node_keys[l])
        np.testing.assert_array_equal(new[: old.shape[0]], old)
    S.check_invariants(g)
    assert S.live_items(g) == ref.live_items()
    # the grown (possibly deeper) tree keeps absorbing deltas
    g = _ingest(g, ref, rng, 6, width=32, universe=1500)
    assert S.live_items(g) == ref.live_items()


@pytest.mark.slow
def test_growth_to_64k_leaves():
    """Sustained ingest to a 64k-leaf pool: the index self-sizes through
    ~8 doublings, stays coherent, and structural cost stays delta-shaped
    (no O(ML) rebuild — asserted via the delta counter equaling the
    number of structural passes).  Excluded from tier-1 via the `slow`
    marker."""
    from repro import api

    rng = np.random.default_rng(64)
    db = api.Uruv(api.UruvConfig(leaf_cap=4, max_leaves=256,
                                 max_versions=1 << 16, index_fanout=16))
    n_keys = 200_000
    keys = rng.choice(20_000_000, n_keys, replace=False).astype(np.int32)
    for i in range(0, n_keys, 4096):
        db.apply(api.OpBatch.inserts(keys[i:i + 4096],
                                     keys[i:i + 4096] % 997 + 1))
    st = db.store
    assert int(st.n_leaves) >= 1 << 15, int(st.n_leaves)
    assert st.cfg.max_leaves >= 1 << 16
    S.check_invariants(st)
    assert db.stats["index_delta_passes"] > 0
    probe = keys[rng.integers(0, n_keys, 4096)]
    got = db.lookup(probe)
    np.testing.assert_array_equal(got, probe % 997 + 1)


# ---------------------------------------------------------------------------
# counters through the client
# ---------------------------------------------------------------------------

def test_client_surfaces_index_counters():
    from repro import api

    db = api.Uruv(_cfg())
    assert db.stats["index_delta_passes"] == 0
    ks = np.arange(0, 200, dtype=np.int32)
    db.apply(api.OpBatch.inserts(ks, ks + 1))
    s = db.stats
    assert s["index_delta_passes"] >= 1
    assert s["index_propagations"] >= 0
    # overwrites ride the light path: no further delta passes
    before = db.stats["index_delta_passes"]
    db.apply(api.OpBatch.inserts(ks, ks + 2))
    assert db.stats["index_delta_passes"] == before
