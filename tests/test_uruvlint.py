"""uruvlint fixture battery (DESIGN.md Sec 13).

Every rule gets three fixtures: a BAD source that must fire, a GOOD
source that must pass, and a suppression variant that silences the bad
source.  Fixtures are inline strings fed through :class:`FileContext`
with synthetic repo-relative paths, so the battery needs no tmp files
and pins each rule's path-scoping logic too.  The battery closes with
the self-lint gate: the merged tree lints clean through the same entry
point scripts/check.sh uses.
"""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis.engine import Allowlist, FileContext, run_contexts
from repro.analysis.marks import DEVICE_PASS_REGISTRY, device_pass
from repro.analysis.reporters import exit_code, render_json, render_text
from repro.analysis.rules import (
    DeterminismRule, DevicePassPurityRule, DonationSafetyRule,
    KernelParityRule, KernelVmemRule, LayeringApiRule, LayeringIndexRule,
    SentinelLiteralRule, default_rules,
)

ROOT = Path(__file__).resolve().parents[1]


def lint(rule, *files):
    """Run one rule over (path, source) fixture pairs."""
    ctxs = [FileContext(p, textwrap.dedent(src)) for p, src in files]
    return run_contexts(ctxs, [rule])


def rule_ids(findings):
    return sorted({f.rule for f in findings})


# ---------------------------------------------------------------------------
# layering-api
# ---------------------------------------------------------------------------

BAD_LAYERING = ("src/repro/serve/engine2.py", """
    from repro.core import store
    from repro.core.batch import apply_updates
    import repro.core.lifecycle
""")


def test_layering_api_bad_fires():
    fs = lint(LayeringApiRule(), BAD_LAYERING)
    assert len(fs) == 3 and rule_ids(fs) == ["layering-api"]
    assert "bypasses repro.api" in fs[0].message


def test_layering_api_good_passes():
    assert lint(LayeringApiRule(), ("src/repro/serve/engine2.py", """
        from repro.api import OpBatch, Uruv
        from repro.core.ref import KEY_MAX          # ref is not restricted
        from repro.core import index
    """)) == []


def test_layering_api_core_and_api_are_exempt():
    src = "from repro.core import store, batch\n"
    assert lint(LayeringApiRule(), ("src/repro/api/client2.py", src)) == []
    assert lint(LayeringApiRule(), ("src/repro/core/lifecycle2.py", src)) == []
    assert len(lint(LayeringApiRule(), ("benchmarks/run2.py", src))) == 2


def test_layering_api_relative_import_resolved():
    fs = lint(LayeringApiRule(), ("src/repro/serve/engine2.py",
                                  "from ..core import store\n"))
    assert rule_ids(fs) == ["layering-api"]


def test_layering_api_suppressed():
    path, src = BAD_LAYERING
    src = "# uruvlint: disable-file=layering-api\n" + textwrap.dedent(src)
    assert lint(LayeringApiRule(), (path, src)) == []


# ---------------------------------------------------------------------------
# layering-index
# ---------------------------------------------------------------------------

def test_layering_index_bad_fires():
    fs = lint(LayeringIndexRule(), ("src/repro/serve/sched.py", """
        import jax.numpy as jnp
        def pick(dir_keys, q):
            return jnp.searchsorted(dir_keys, q)
    """))
    assert len(fs) >= 2 and rule_ids(fs) == ["layering-index"]


def test_layering_index_allowed_files_pass():
    src = "def f(dir_keys, q):\n    return searchsorted(dir_keys, q)\n"
    for p in ("src/repro/core/index.py", "src/repro/core/backend.py",
              "src/repro/core/baseline.py",
              "src/repro/kernels/uruv_search/ops.py"):
        assert lint(LayeringIndexRule(), (p, src)) == []


def test_layering_index_suppressed():
    fs = lint(LayeringIndexRule(), ("src/repro/serve/sched.py",
        "x = dir_keys  # uruvlint: disable=layering-index\n"))
    assert fs == []


# ---------------------------------------------------------------------------
# device-pass-purity
# ---------------------------------------------------------------------------

BAD_PURITY = ("src/repro/core/hot.py", """
    import numpy as np
    from repro.analysis.marks import device_pass

    @device_pass
    def hot(store, keys):
        n = int(store.ts)              # host sync
        h = np.asarray(keys)           # host transfer
        keys.block_until_ready()       # host sync
        if store:                      # python branch on traced value
            return n, h
""")


def test_purity_bad_fires():
    fs = lint(DevicePassPurityRule(), BAD_PURITY)
    msgs = " | ".join(f.message for f in fs)
    assert rule_ids(fs) == ["device-pass-purity"] and len(fs) == 4
    assert "int()" in msgs and "np.asarray" in msgs
    assert "block_until_ready" in msgs and "`if`" in msgs


def test_purity_good_passes():
    assert lint(DevicePassPurityRule(), ("src/repro/core/hot.py", """
        import jax.numpy as jnp
        from repro.analysis.marks import device_pass

        @device_pass(static=("backend",))
        def hot(store, keys, base_ts=None, *, backend):
            if base_ts is None:        # optional-arg check: host-static
                base_ts = store.ts
            if backend == "xla":       # static param: legal dispatch
                return jnp.where(keys > 0, keys, base_ts)
            return keys
    """)) == []


def test_purity_unmarked_function_ignored():
    path, src = BAD_PURITY
    src = textwrap.dedent(src).replace("@device_pass\ndef hot", "def hot")
    assert lint(DevicePassPurityRule(), (path, src)) == []


def test_purity_suppressed_line():
    path, src = BAD_PURITY
    src = src.replace("n = int(store.ts)              # host sync",
                      "n = int(store.ts)  # uruvlint: disable=device-pass-purity")
    assert len(lint(DevicePassPurityRule(), (path, src))) == 3


# ---------------------------------------------------------------------------
# donation-safety
# ---------------------------------------------------------------------------

BAD_DONATION = ("src/repro/api/pipe.py", """
    import functools, jax

    @functools.partial(jax.jit, donate_argnums=(0,))
    def pass_dstore(store, keys):
        return store

    def caller(store, keys):
        out = pass_dstore(store, keys)
        return store.ts                # use-after-donation
""")


def test_donation_bad_fires():
    fs = lint(DonationSafetyRule(), BAD_DONATION)
    assert rule_ids(fs) == ["donation-safety"] and len(fs) == 1
    assert "after it was donated" in fs[0].message


def test_donation_rebind_passes():
    path, src = BAD_DONATION
    src = src.replace("out = pass_dstore(store, keys)\n        return store.ts"
                      "                # use-after-donation",
                      "store = pass_dstore(store, keys)\n        return store.ts")
    assert lint(DonationSafetyRule(), (path, src)) == []


def test_donation_donate_store_keyword_taints_store_args_only():
    fs = lint(DonationSafetyRule(), ("src/repro/serve/x.py", """
        def go(ex, store, plan):
            ex.apply(store, plan, donate_store=True)
            a = plan                   # plan was NOT donated
            return store.ts            # store WAS
    """))
    assert len(fs) == 1 and "'store'" in fs[0].message


def test_donation_branch_isolation():
    # a donation inside one branch must not poison uses earlier in it
    assert lint(DonationSafetyRule(), ("src/repro/serve/x.py", """
        def go(ex, store, flag):
            if flag:
                n = store.ts
                ex.apply(store, donate_store=True)
            return n
    """)) == []


def test_donation_suppressed():
    path, src = BAD_DONATION
    src = src.replace("return store.ts                # use-after-donation",
                      "return store.ts  # uruvlint: disable=donation-safety")
    assert lint(DonationSafetyRule(), (path, src)) == []


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------

BAD_DETERMINISM = ("src/repro/core/batch2.py", """
    import time
    import numpy as np
    def stamp(ops):
        seed = time.time()
        noise = np.random.rand()
        for k in {1, 2, 3}:
            pass
        return seed, noise
""")


def test_determinism_bad_fires():
    fs = lint(DeterminismRule(), BAD_DETERMINISM)
    assert rule_ids(fs) == ["determinism"] and len(fs) >= 3


def test_determinism_scope_is_core_only():
    path, src = BAD_DETERMINISM
    assert lint(DeterminismRule(), ("src/repro/serve/metrics.py", src)) == []
    assert lint(DeterminismRule(), ("benchmarks/run2.py", src)) == []


def test_determinism_covers_durability_replay_path():
    # the WAL replay path is a deterministic-by-construction contract
    # (DESIGN.md Sec 14): wall clock / host RNG fire there too
    path, src = BAD_DETERMINISM
    fs = lint(DeterminismRule(), ("src/repro/durability/wal2.py", src))
    assert rule_ids(fs) == ["determinism"] and len(fs) >= 3


def test_determinism_os_urandom_fires_but_os_io_ok():
    fs = lint(DeterminismRule(), ("src/repro/durability/wal2.py", """
        import os
        def seg_id(f):
            os.fsync(f.fileno())           # durable I/O is fine
            return os.urandom(8)           # entropy is not
    """))
    assert rule_ids(fs) == ["determinism"]
    assert len(fs) == 1 and "os.urandom" in fs[0].message


def test_determinism_jax_random_ok():
    assert lint(DeterminismRule(), ("src/repro/core/batch2.py", """
        import jax
        def stamp(ops, key):
            return jax.random.bits(key)
    """)) == []


def test_determinism_suppressed():
    path, src = BAD_DETERMINISM
    src = "# uruvlint: disable-file=determinism\n" + textwrap.dedent(src)
    assert lint(DeterminismRule(), (path, src)) == []


# ---------------------------------------------------------------------------
# kernel-parity
# ---------------------------------------------------------------------------

GOOD_KERNEL = """
    def scan(keys, vals, *, block_q=128, interpret=True):
        return keys
"""
GOOD_REF = """
    def scan_ref(keys, vals):
        return keys
"""


def test_kernel_parity_good_passes():
    assert lint(KernelParityRule(),
                ("src/repro/kernels/foo/foo.py", GOOD_KERNEL),
                ("src/repro/kernels/foo/ref.py", GOOD_REF)) == []


def test_kernel_parity_positional_mismatch_fires():
    fs = lint(KernelParityRule(),
              ("src/repro/kernels/foo/foo.py", GOOD_KERNEL),
              ("src/repro/kernels/foo/ref.py",
               "def scan_ref(keys, wrong_name):\n    return keys\n"))
    assert rule_ids(fs) == ["kernel-parity"]


def test_kernel_parity_missing_twin_fires():
    fs = lint(KernelParityRule(),
              ("src/repro/kernels/foo/foo.py",
               textwrap.dedent(GOOD_KERNEL) + "\ndef other(a):\n    return a\n"),
              ("src/repro/kernels/foo/ref.py", GOOD_REF))
    assert any("no oracle twin" in f.message for f in fs)


def test_kernel_parity_ref_extra_kwonly_fires():
    fs = lint(KernelParityRule(),
              ("src/repro/kernels/foo/foo.py", GOOD_KERNEL),
              ("src/repro/kernels/foo/ref.py",
               "def scan_ref(keys, vals, *, exotic=1):\n    return keys\n"))
    assert any("missing from kernel" in f.message for f in fs)


# ---------------------------------------------------------------------------
# kernel-vmem
# ---------------------------------------------------------------------------

VMEM_SRC = """
    import functools
    from jax.experimental import pallas as pl

    def launch(x, *, block_q={bq}):
        return pl.pallas_call(
            kernel,
            out_shape=x,
            in_specs=[pl.BlockSpec((block_q, 4096), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((block_q, 4096), lambda i: (i, 0)),
        )(x)
"""


def test_kernel_vmem_over_budget_fires():
    fs = lint(KernelVmemRule(budget=1 << 20),
              ("src/repro/kernels/foo/foo.py", VMEM_SRC.format(bq=4096)))
    assert rule_ids(fs) == ["kernel-vmem"]
    assert "budget" in fs[0].message


def test_kernel_vmem_small_blocks_pass():
    assert lint(KernelVmemRule(budget=1 << 20),
                ("src/repro/kernels/foo/foo.py", VMEM_SRC.format(bq=8))) == []


def test_kernel_vmem_scope_is_kernels_only():
    assert lint(KernelVmemRule(budget=1),
                ("src/repro/serve/x.py", VMEM_SRC.format(bq=4096))) == []


def test_kernel_vmem_min_bound_used():
    # bq = min(block_q, P): the 16 bound applies even though P is unknown
    src = VMEM_SRC.format(bq=4096).replace(
        "        return pl.pallas_call(",
        "        bq = min(16, P)\n        return pl.pallas_call(").replace(
        "(block_q, 4096)", "(bq, 4096)")
    assert lint(KernelVmemRule(budget=1 << 20),
                ("src/repro/kernels/foo/foo.py", src)) == []


# ---------------------------------------------------------------------------
# sentinel-literal
# ---------------------------------------------------------------------------

BAD_SENTINEL = ("src/repro/serve/hashing.py", """
    PAD = 2**31 - 1
    KPAD = 0x7FFFFFFF - 1
    HI = 2147483645
""")


def test_sentinel_bad_fires():
    fs = lint(SentinelLiteralRule(), BAD_SENTINEL)
    assert rule_ids(fs) == ["sentinel-literal"] and len(fs) >= 3
    assert "core/ref.py" in fs[0].message


def test_sentinel_blessed_module_passes():
    assert lint(SentinelLiteralRule(),
                ("src/repro/core/ref.py", BAD_SENTINEL[1])) == []


def test_sentinel_unrelated_literals_pass():
    assert lint(SentinelLiteralRule(), ("src/repro/serve/hashing.py", """
        FNV = 16777619
        MASK = 2**16 - 1
    """)) == []


def test_sentinel_suppressed():
    fs = lint(SentinelLiteralRule(), ("src/repro/serve/hashing.py",
        "PAD = 2**31 - 1  # uruvlint: disable=sentinel-literal\n"))
    assert fs == []


# ---------------------------------------------------------------------------
# engine mechanics: allowlist, dedup, parse errors, reporters
# ---------------------------------------------------------------------------

def test_allowlist_filters_by_rule_and_glob():
    allow = Allowlist([("sentinel-literal", "src/repro/serve/*")])
    ctx = FileContext(BAD_SENTINEL[0], textwrap.dedent(BAD_SENTINEL[1]))
    assert run_contexts([ctx], [SentinelLiteralRule()], allow) == []
    # a different rule id still fires through the same glob
    ctx2 = FileContext(BAD_LAYERING[0], textwrap.dedent(BAD_LAYERING[1]))
    assert run_contexts([ctx2], [LayeringApiRule()], allow) != []


def test_reporters_text_json_exit_code():
    fs = lint(SentinelLiteralRule(), BAD_SENTINEL)
    text = render_text(fs, 1)
    assert "sentinel-literal" in text and "finding(s)" in text
    doc = json.loads(render_json(fs, 1))
    assert doc["version"] == 1 and doc["files"] == 1
    assert doc["counts"]["sentinel-literal"] == len(fs)
    assert {f["rule"] for f in doc["findings"]} == {"sentinel-literal"}
    assert exit_code(fs) == 1 and exit_code([]) == 0
    assert "clean" in render_text([], 3)


def test_device_pass_registry_populated():
    @device_pass(static=("backend",))
    def probe(store, *, backend):
        return store

    key = f"{probe.__module__}.{probe.__qualname__}"
    assert DEVICE_PASS_REGISTRY[key] == ("backend",)
    assert probe("s", backend="xla") == "s"     # identity at runtime
    # the real hot paths registered on import
    import repro.core.store  # noqa: F401
    assert any(k.endswith("_bulk_apply_impl") for k in DEVICE_PASS_REGISTRY)


# ---------------------------------------------------------------------------
# self-lint: the merged tree is clean through the CLI check.sh runs
# ---------------------------------------------------------------------------

def test_self_lint_src_clean():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "src/"],
        cwd=ROOT, capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"},
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "clean" in proc.stdout


def test_self_lint_full_default_paths_clean():
    from repro.analysis.engine import run_paths

    findings = run_paths(
        [ROOT / p for p in ("src/repro", "benchmarks", "examples", "scripts")],
        rules=default_rules(), root=ROOT)
    assert findings == [], "\n".join(f.render() for f in findings)
