"""End-to-end behaviour tests for the paper's system.

1. The full paper workflow on one store: streaming ingestion + wait-free
   updates + linearizable range scans + GC (the Uruv ADT contract).
2. The framework loop: train a reduced LM with checkpoints, crash, restart,
   serve it with prefix-cached continuous batching.
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.config import get_arch
from repro.core import batch as B
from repro.core import store as S
from repro.core.ref import RefStore, OP_INSERT
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import make_batch
from repro.distributed.fault import run_with_restarts
from repro.models.registry import get_model
from repro.optim import adamw
from repro.serve.engine import Engine, Request
from repro.train import steps


def test_paper_workflow_end_to_end():
    """Prefill -> concurrent update/scan mix -> delete wave -> GC; oracle-
    checked at every stage (the paper's Sec 6 workload in miniature)."""
    rng = np.random.default_rng(0)
    store = S.create(S.UruvConfig(leaf_cap=16, max_leaves=1024,
                                  max_versions=1 << 15, max_chain=32))
    ref = RefStore()

    # prefill (paper: uniform keys from a universe)
    keys = rng.choice(5000, 1500, replace=False).astype(np.int32)
    for i in range(0, len(keys), 128):
        ch = keys[i:i+128]
        store, _ = B.apply_updates(store, ch, ch)
        ref.apply_batch([(OP_INSERT, int(k), int(k)) for k in ch])

    # interleaved updates + snapshot scans
    snaps = []
    for round_ in range(5):
        store, snap = S.snapshot(store)
        rs = ref.snapshot()
        snaps.append((int(snap), rs, ref.range_query(1000, 3000, rs)))
        upd = rng.choice(5000, 200).astype(np.int32)
        vals = rng.integers(0, 10**6, 200).astype(np.int32)
        store, _ = B.apply_updates(store, upd, vals)
        ref.apply_batch([(OP_INSERT, int(k), int(v))
                         for k, v in zip(upd, vals)])
    for snap, rs, want in snaps:
        store, got = B.range_query_all(store, 1000, 3000, snap)
        assert got == want
    # release all, GC, verify latest state intact
    for snap, rs, _ in snaps:
        store = S.release(store, snap)
        ref.release(rs)
    before = int(store.n_vers)
    store, _ = S.compact(store)
    assert int(store.n_vers) < before
    assert S.live_items(store) == ref.live_items()
    S.check_invariants(store)


def test_framework_train_crash_serve(tmp_path):
    cfg = get_arch("llama3_2_1b").reduced()
    opt = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=25)
    step_fn = jax.jit(steps.make_train_step(cfg, opt))
    mgr = CheckpointManager(tmp_path, keep=2, async_write=False)

    state, hist = run_with_restarts(
        init_fn=lambda: steps.init_state(cfg, jax.random.key(0)),
        step_fn=step_fn,
        batch_fn=lambda s: make_batch(cfg, 4, 32, s),
        ckpt=mgr, total_steps=25, ckpt_every=5, crash_at=[12],
    )
    losses = [l for k, s, l in hist if k == "step"]
    assert any(k == "restart" for k, *_ in hist)
    assert losses[-1] < losses[0], "loss should decrease"

    # serve the trained params
    api = get_model(cfg)
    eng = Engine(cfg, state.params, n_slots=2, max_len=48)
    reqs = [Request(rid=i, prompt=[3, 1, 4, 1, 5], max_new=4)
            for i in range(3)]
    eng.run(reqs)
    assert all(r.done and len(r.out) == 4 for r in reqs)
    # deterministic greedy decode: identical prompts -> identical outputs
    assert reqs[0].out == reqs[1].out == reqs[2].out
