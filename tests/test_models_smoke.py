"""Per-architecture smoke tests: reduced config, forward + train step on
CPU, exact output shapes, finite values; decode == parallel forward."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.config import ARCH_IDS, SHAPES, get_arch, shape_applicable
from repro.data.pipeline import make_batch
from repro.models.registry import get_model, input_specs
from repro.optim import adamw
from repro.train import steps


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_reduced_forward_and_train_step(arch_id):
    cfg = get_arch(arch_id).reduced()
    api = get_model(cfg)
    B, S = 2, 16
    state = steps.init_state(cfg, jax.random.key(0))
    batch = make_batch(cfg, B, S, step=0)
    step_fn = jax.jit(steps.make_train_step(cfg, adamw.AdamWConfig()))
    new_state, metrics = step_fn(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_state.step) == 1
    # params actually changed
    d0 = jax.tree.leaves(state.params)[1]
    d1 = jax.tree.leaves(new_state.params)[1]
    assert not np.array_equal(np.asarray(d0), np.asarray(d1))


@pytest.mark.parametrize("arch_id", ["qwen3_4b", "olmoe_1b_7b",
                                     "deepseek_moe_16b", "internvl2_76b"])
def test_decode_matches_parallel_forward(arch_id):
    cfg = get_arch(arch_id).reduced()
    api = get_model(cfg)
    params = api.init(cfg, jax.random.key(1))
    rng = np.random.default_rng(0)
    B, S = 2, 8
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    kw = {"tokens": toks}
    if cfg.vlm is not None:
        kw["patches"] = jnp.asarray(
            rng.standard_normal((B, cfg.vlm.n_patches, cfg.vlm.patch_dim)),
            jnp.float32)
    logits, _ = jax.jit(
        lambda p, kw: api.forward_train(cfg, p, **kw))(params, kw)
    if cfg.vlm is not None:
        pytest.skip("vlm decode covered by dryrun (patch prefix cacheless)")
    cache = api.init_cache(cfg, B, S + 2)
    lens = jnp.zeros((B,), jnp.int32)
    step = jax.jit(lambda p, t, c, l: api.decode_step(cfg, p, t, c, l))
    for t in range(S):
        lg, cache = step(params, toks[:, t], cache, lens)
        lens = lens + 1
    np.testing.assert_allclose(np.asarray(lg), np.asarray(logits[:, -1]),
                               atol=5e-3, rtol=5e-3)


def test_transformer_prefill_matches_step_decode():
    from repro.models import transformer

    cfg = get_arch("llama3_2_1b").reduced()
    api = get_model(cfg)
    params = api.init(cfg, jax.random.key(2))
    rng = np.random.default_rng(1)
    B, S, Smax = 2, 6, 10
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    logits_pf, cache_pf = jax.jit(
        lambda p, t: transformer.prefill(cfg, p, t, Smax))(params, toks)
    # continue one decode step from the prefilled cache
    lens = jnp.full((B,), S, jnp.int32)
    nxt = jnp.argmax(logits_pf[:, -1], -1).astype(jnp.int32)
    lg1, _ = api.decode_step(cfg, params, nxt, cache_pf, lens)
    # reference: fully step-by-step
    cache = api.init_cache(cfg, B, Smax)
    lens2 = jnp.zeros((B,), jnp.int32)
    for t in range(S):
        lg, cache = api.decode_step(cfg, params, toks[:, t], cache, lens2)
        lens2 = lens2 + 1
    lg2, _ = api.decode_step(cfg, params, nxt, cache, lens2)
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2),
                               atol=5e-3, rtol=5e-3)


def test_gemma_window_pattern():
    from repro.models.transformer import layer_windows

    cfg = get_arch("gemma3_1b")
    w = layer_windows(cfg)
    assert w.shape == (26,)
    assert (w[5::6] == 0).all()            # every 6th layer global
    assert (w[0:5] == 1024).all()


def test_moe_balance_losses_present():
    cfg = get_arch("olmoe_1b_7b").reduced()
    api = get_model(cfg)
    params = api.init(cfg, jax.random.key(0))
    toks = jnp.zeros((2, 16), jnp.int32)
    _, aux = api.forward_train(cfg, params, tokens=toks)
    assert "moe_balance" in aux and np.isfinite(float(aux["moe_balance"]))
    assert float(aux["moe_dropped"]) < 0.9


def test_input_specs_cover_all_cells():
    for arch_id in ARCH_IDS:
        cfg = get_arch(arch_id)
        for shape in SHAPES.values():
            ok, _ = shape_applicable(cfg, shape)
            if not ok:
                continue
            specs = input_specs(cfg, shape)
            leaves = jax.tree.leaves(specs)
            assert leaves, (arch_id, shape.name)
            for l in leaves:
                assert all(d > 0 for d in l.shape)


def test_grad_accum_equivalence():
    import dataclasses

    cfg = get_arch("llama3_2_1b").reduced()
    cfg1 = dataclasses.replace(cfg, accum_steps=1, remat=False)
    cfg2 = dataclasses.replace(cfg, accum_steps=2, remat=False)
    state = steps.init_state(cfg1, jax.random.key(3))
    batch = make_batch(cfg1, 4, 16, step=0)
    opt = adamw.AdamWConfig()
    s1, m1 = jax.jit(steps.make_train_step(cfg1, opt))(state, batch)
    s2, m2 = jax.jit(steps.make_train_step(cfg2, opt))(state, batch)
    # microbatch mean-of-means == full-batch mean here (equal split sizes)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=2e-4)
    l1 = jax.tree.leaves(s1.params)[1]
    l2 = jax.tree.leaves(s2.params)[1]
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               atol=2e-4, rtol=2e-3)
