"""Activation-sharding context: spec selection + divisibility guards.

These are the rules whose violation caused §Perf iteration 1 (TB-scale
cache re-gathers), so they get their own regression tests."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.ctx import ShardCtx, _guard, shard_act, use_mesh
from repro.launch.mesh import make_host_mesh


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
POD = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_kv4_matches_batch_layout():
    ctx = ShardCtx(MESH)
    assert tuple(ctx.spec("kv4", 4)) == ("data", None, "model", None)


def test_kv4_long_context_seq_over_all():
    ctx = ShardCtx(MESH, long_context=True)
    spec = tuple(ctx.spec("kv4", 4))
    assert spec[2] == ("data", "model")
    ctx = ShardCtx(POD, long_context=True)
    assert tuple(ctx.spec("kv4", 4))[2] == ("pod", "data", "model")


def test_residual_sequence_parallel_toggle():
    on = ShardCtx(MESH, sequence_parallel=True)
    off = ShardCtx(MESH, sequence_parallel=False)
    assert tuple(on.spec("residual", 3)) == ("data", "model", None)
    assert tuple(off.spec("residual", 3)) == ("data", None, None)


def test_moe_specs():
    ctx = ShardCtx(MESH)
    assert tuple(ctx.spec("moe_experts", 3)) == ("model", "data", None)
    assert tuple(ctx.spec("moe_weight", 3)) == ("model", None, None)


def test_guard_drops_nondivisible_axes():
    spec = _guard(P("data", None, "model", None),
                  (24, 5, 2048, 64), MESH)
    # 24 % 16 != 0 -> replicated; 2048 % 16 == 0 -> kept
    assert tuple(spec) == (None, None, "model", None)
    spec = _guard(P(("pod", "data"), None), (64, 8), POD)
    assert tuple(spec) == (("pod", "data"), None)
    spec = _guard(P(("pod", "data"), None), (33, 8), POD)
    assert tuple(spec) == (None, None)


def test_shard_act_noop_outside_ctx():
    import jax.numpy as jnp

    x = jnp.zeros((4, 8))
    assert shard_act(x, "residual") is x


def test_shard_act_applies_constraint_under_mesh():
    import jax.numpy as jnp

    mesh = make_host_mesh(1, 1)
    with use_mesh(mesh):
        def f(x):
            return shard_act(x, "residual") * 2

        out = jax.jit(f)(jnp.ones((2, 4, 8)))
        assert out.shape == (2, 4, 8)
