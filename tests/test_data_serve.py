"""Data pipeline determinism + streaming store; serving engine correctness
(prefix reuse must not change outputs)."""

import numpy as np
import jax
import pytest

from repro.config import get_arch
from repro.data.pipeline import (
    StreamingSampleStore, SyntheticCorpus, epoch_iterator, make_batch,
)
from repro.models.registry import get_model
from repro.serve.engine import Engine, Request, prefix_hash


def test_make_batch_deterministic():
    cfg = get_arch("llama3_2_1b").reduced()
    b1 = make_batch(cfg, 2, 16, step=5)
    b2 = make_batch(cfg, 2, 16, step=5)
    b3 = make_batch(cfg, 2, 16, step=6)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    # next-token alignment
    np.testing.assert_array_equal(np.asarray(b1["tokens"])[:, 1:],
                                  np.asarray(b1["labels"])[:, :-1])


def test_streaming_store_ingest_epoch_retire():
    store = StreamingSampleStore()
    ids = np.arange(100, dtype=np.int32)
    store.ingest(ids[:60], ids[:60] * 10)
    snap = store.epoch_view()
    # concurrent ingestion does not disturb the epoch view
    store.ingest(ids[60:], ids[60:] * 10)
    shard = store.read_shard(0, 99, snap)
    assert [k for k, _ in shard] == list(range(60))
    store.release(snap)
    assert store.live_count() == 100
    store.retire_below(50)
    store.compact()
    assert store.live_count() == 50


def test_epoch_iterator_batches():
    cfg = get_arch("llama3_2_1b").reduced()
    store = StreamingSampleStore()
    ids = np.arange(8, dtype=np.int32)
    store.ingest(ids, ids + 100)
    corpus = SyntheticCorpus(cfg.vocab)
    batches = list(epoch_iterator(store, corpus, cfg, B=4, S=16))
    assert len(batches) == 2
    assert batches[0]["tokens"].shape == (4, 16)


def test_prefix_hash_stable():
    assert prefix_hash([1, 2, 3]) == prefix_hash([1, 2, 3])
    assert prefix_hash([1, 2, 3]) != prefix_hash([1, 2, 4])


def test_select_donor_covers_plen():
    """Regression for the `and`/`or` precedence bug in the donor condition:
    a hit is usable iff the cached entry covers the probed prefix
    (ln >= plen), independent of the donor slot's live/idle state."""
    pack = lambda slot, ln: (slot << 16) | ln
    # longest covered prefix wins
    donor = Engine._select_donor([1, 2, 3], [pack(0, 1), pack(1, 2), -1])
    assert donor == (1, 2)
    # entry shorter than the probed prefix (hash collision) must NOT match
    donor = Engine._select_donor([3], [pack(0, 2)])
    assert donor == (-1, 0)
    # no hits at all
    assert Engine._select_donor([1, 2], [-1, -1]) == (-1, 0)


def test_lookup_prefix_uses_completed_donor(tiny_engine_setup):
    """A donor whose request already completed (slot_req None) still serves
    prefix hits: its KV stays valid until the slot is re-admitted."""
    cfg, api, params = tiny_engine_setup
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, 6).tolist()
    eng = Engine(cfg, params, n_slots=2, max_len=32)
    r = Request(rid=0, prompt=prompt, max_new=2)
    eng.run([r])
    assert r.done and all(s is None for s in eng.slot_req)
    donor, plen = eng._lookup_prefix(prompt + [1])
    assert donor >= 0
    assert plen == len(prompt)


@pytest.fixture(scope="module")
def tiny_engine_setup():
    cfg = get_arch("llama3_2_1b").reduced()
    api = get_model(cfg)
    params = api.init(cfg, jax.random.key(0))
    return cfg, api, params


def test_engine_generates_and_reuses_prefix(tiny_engine_setup):
    cfg, api, params = tiny_engine_setup
    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab, 5).tolist()
    p1 = shared + [7, 8]
    p2 = shared + [9]

    # run WITHOUT reuse (separate engines)
    outs_ref = []
    for p in (p1, p2):
        eng = Engine(cfg, params, n_slots=2, max_len=32)
        r = Request(rid=0, prompt=p, max_new=5)
        eng.run([r])
        outs_ref.append(r.out)

    # run WITH shared engine (second request may reuse the prefix)
    eng = Engine(cfg, params, n_slots=2, max_len=32)
    r1 = Request(rid=1, prompt=p1, max_new=5)
    eng.run([r1])
    r2 = Request(rid=2, prompt=p2, max_new=5)
    eng.run([r2])
    assert r1.out == outs_ref[0]
    assert r2.out == outs_ref[1], "prefix reuse changed generation output"
    view = eng.snapshot_view()
    assert len(view) > 0
    # batched fan-out: N bounded views at ONE snapshot tile the full view
    mid = view[len(view) // 2][0]
    lo_v, hi_v = eng.snapshot_views([(0, mid), (mid + 1, 2**31 - 3)])
    assert lo_v + hi_v == view
    assert eng.table.active_snapshots == 0                   # all released


def test_engine_continuous_batching(tiny_engine_setup):
    cfg, api, params = tiny_engine_setup
    rng = np.random.default_rng(1)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, 3 + i).tolist(),
                max_new=4)
        for i in range(5)
    ]
    eng = Engine(cfg, params, n_slots=2, max_len=32)   # fewer slots than reqs
    eng.run(reqs)
    assert all(r.done and len(r.out) == 4 for r in reqs)
