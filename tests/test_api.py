"""repro.api — the public client surface (ISSUE 3).

OpBatch/Result pytree + jit/donation safety, one-compile-per-shape through
the client, client-vs-oracle linearization, deprecation shims (warning +
bit-exact equivalence with the client path), snapshot-context hygiene,
the layering gate (non-core modules go through repro.api only), and
sharded-executor equivalence on 4 fake devices (subprocess).
"""

import subprocess
import sys
import warnings
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.api import (
    KEY_MAX, NOT_FOUND, TOMBSTONE, OP_INSERT, OP_NOP, OP_RANGE, OP_SEARCH,
    OpBatch, RangePage, Result, Uruv, UruvConfig, make_result,
)
from repro.core.ref import OP_DELETE, RefStore

CFG = UruvConfig(leaf_cap=8, max_leaves=512, max_versions=1 << 14,
                 max_chain=16)


def mixed_plan():
    return OpBatch.concat(
        OpBatch.searches([5, 7]),
        OpBatch.inserts([5, 7, 9], [50, 70, 90]),
        OpBatch.ranges([0, 6], [8, 2**31 - 3]),
        OpBatch.deletes([7]),
        OpBatch.searches([7]),
    )


def plan_ops(batch: OpBatch):
    return [(int(c), int(k), int(v)) for c, k, v in
            zip(np.asarray(batch.codes), np.asarray(batch.keys),
                np.asarray(batch.values))]


# ---------------------------------------------------------------------------
# OpBatch / Result: pytree + jit + donation safety
# ---------------------------------------------------------------------------

def test_opbatch_pytree_roundtrip():
    b = mixed_plan()
    leaves, treedef = jax.tree_util.tree_flatten(b)
    assert all(isinstance(l, np.ndarray) for l in leaves)
    b2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(b2, OpBatch)
    for f in ("codes", "keys", "values"):
        np.testing.assert_array_equal(getattr(b, f), getattr(b2, f))


def test_result_pytree_roundtrip():
    res = make_result(
        np.array([1, NOT_FOUND, 3], np.int64),
        np.array([OP_INSERT, OP_NOP, OP_RANGE], np.int32),
        base_ts=7,
        range_items=[(2, [(4, 40), (5, 50)], 9)],
    )
    leaves, treedef = jax.tree_util.tree_flatten(res)
    res2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(res2, Result)
    assert res2.values.tolist() == [1, NOT_FOUND, 3]
    assert res2.found.tolist() == [True, False, True]
    assert res2.timestamps.tolist() == [7, 8, 9]
    assert res2.page(2) == [(4, 40), (5, 50)]
    assert res2.range_resume.tolist() == [9]


def test_opbatch_jit_and_donation_safe():
    b = OpBatch(jnp.asarray([OP_INSERT] * 4, jnp.int32),
                jnp.arange(4, dtype=jnp.int32),
                jnp.full((4,), 3, jnp.int32))

    @jax.jit
    def through(batch):
        merged = OpBatch.concat(batch, batch).pad_to(16)
        return OpBatch(merged.codes, merged.keys, merged.values + 1)

    out = through(b)
    assert len(out) == 16
    assert out.values[:4].tolist() == [4, 4, 4, 4]
    assert int(out.keys[-1]) == KEY_MAX            # NOP padding
    assert int(out.codes[-1]) == OP_NOP

    @jax.jit
    def donating(batch):
        return OpBatch(batch.codes, batch.keys * 2, batch.values)

    donating_d = jax.jit(
        lambda batch: OpBatch(batch.codes, batch.keys * 2, batch.values),
        donate_argnums=0,
    )
    with warnings.catch_warnings():
        # CPU backend may decline the donation; aliasing must still be safe
        warnings.simplefilter("ignore")
        out = donating_d(b)
    assert out.keys.tolist() == [0, 2, 4, 6]


def test_result_jit_safe():
    res = make_result(
        np.array([1, 2], np.int64), np.array([OP_INSERT, OP_INSERT], np.int32),
        base_ts=0,
    )
    bumped = jax.jit(
        lambda r: Result(r.values + 1, r.found, r.timestamps, r.range_index,
                         r.range_pages, r.range_resume)
    )(res)
    assert np.asarray(bumped.values).tolist() == [2, 3]


def test_opbatch_builders_and_pad():
    b = OpBatch.updates(np.array([1, KEY_MAX, 3], np.int32),
                        np.array([10, 0, TOMBSTONE], np.int32))
    assert b.codes.tolist() == [OP_INSERT, OP_NOP, OP_DELETE]
    with pytest.raises(ValueError):
        b.pad_to(2)
    p = b.pad_to(5)
    assert p.codes.tolist()[3:] == [OP_NOP, OP_NOP]
    assert p.keys.tolist()[3:] == [KEY_MAX, KEY_MAX]
    assert mixed_plan().range_positions.tolist() == [5, 6]


def test_pow2_width_and_pad_to_pow2():
    """The serving front end's shape-bucketing helpers: `pow2_width` is
    next-power-of-two (1 for empty), `pad_to_pow2` NOP-pads to it with
    the KEY_MAX sentinel the builders themselves can never emit (they
    reject both sentinel keys at the front door)."""
    from repro.api import pow2_width
    assert [pow2_width(n) for n in (0, 1, 2, 3, 4, 5, 8, 9)] == \
        [1, 1, 2, 4, 4, 8, 8, 16]
    b = OpBatch.inserts([1, 2, 3], [7, 7, 7]).pad_to_pow2()
    assert len(b) == 4
    assert b.codes.tolist()[-1] == OP_NOP and b.keys.tolist()[-1] == KEY_MAX
    assert len(OpBatch.searches([1, 2]).pad_to_pow2()) == 2  # already pow2
    with pytest.raises(ValueError, match="sentinel"):
        OpBatch.inserts([KEY_MAX - 1], [1])


# ---------------------------------------------------------------------------
# One compile per shape through the client
# ---------------------------------------------------------------------------

def test_one_compile_per_shape_through_client():
    from repro.core import store as S

    db = Uruv(CFG)
    rng = np.random.default_rng(0)
    for i in range(0, 64, 8):                   # spread prefill: fast-path
        db.insert(np.arange(i, i + 8, dtype=np.int32), 0)  # overwrites below
    W = 37                                      # distinctive width
    cache0 = S._bulk_apply._cache_size()

    def batch():                                # overwrite-only: light path
        return OpBatch.inserts(rng.integers(0, 64, W).astype(np.int32),
                               rng.integers(0, 100, W).astype(np.int32))

    passes0 = db.stats["device_passes"]
    db.apply(batch())
    grown = S._bulk_apply._cache_size() - cache0
    assert grown >= 1
    for _ in range(4):                          # same shape: NO retrace
        db.apply(batch())
    assert S._bulk_apply._cache_size() - cache0 == grown
    # ... and the fast path stays one device pass per batch
    assert db.stats["device_passes"] - passes0 == 5

    # pad_to_pow2 buckets ragged widths into one shape
    cache1 = S._bulk_apply._cache_size()
    for w in (33, 40, 57, 64):
        db.apply(OpBatch.searches(rng.integers(0, 30, w).astype(np.int32)),
                 pad_to_pow2=True)
    assert S._bulk_apply._cache_size() - cache1 <= 1


# ---------------------------------------------------------------------------
# Client linearization vs the sequential oracle
# ---------------------------------------------------------------------------

def test_client_mixed_plan_vs_oracle():
    db = Uruv(CFG)
    ref = RefStore()
    plan = mixed_plan()
    res = db.apply(plan)
    want = ref.apply_batch(plan_ops(plan))
    assert res.values.tolist() == want
    assert db.ts == ref.ts
    assert res.timestamps.tolist() == list(range(ref.ts - len(plan), ref.ts))
    # complete pages at the range ops' announce snapshots
    assert res.range_index.tolist() == [5, 6]
    assert res.page(5) == ref.range_query(0, 8, int(res.timestamps[5]))
    assert res.page(6) == ref.range_query(6, 2**31 - 3,
                                          int(res.timestamps[6]))
    assert db.live_items() == ref.live_items()
    assert res.found.tolist() == [v != NOT_FOUND for v in want]


def test_client_random_plans_vs_oracle():
    rng = np.random.default_rng(42)
    db = Uruv(CFG)
    ref = RefStore()
    for it in range(6):
        n = int(rng.integers(1, 40))
        codes = rng.choice(
            [OP_INSERT, OP_INSERT, OP_DELETE, OP_SEARCH, OP_RANGE, OP_NOP], n
        ).astype(np.int32)
        keys = rng.integers(0, 60, n).astype(np.int32)
        vals = rng.integers(0, 1000, n).astype(np.int32)
        vals = np.where(codes == OP_RANGE, keys + rng.integers(0, 30, n),
                        vals).astype(np.int32)
        batch = OpBatch(codes, keys, vals)
        res = db.apply(batch)
        want = ref.apply_batch(plan_ops(batch))
        assert res.values.tolist() == want, it
        assert db.ts == ref.ts
    assert db.live_items() == ref.live_items()


def test_client_verbs_and_lookup():
    db = Uruv(CFG)
    db.insert([1, 2, 3], [10, 20, 30])
    assert db.lookup([1, 2, 99]).tolist() == [10, 20, NOT_FOUND]
    assert db.lookup([1, 2, 99], pad_to_pow2=True).tolist() == \
        [10, 20, NOT_FOUND]
    prev = db.delete([2])
    assert prev.values.tolist() == [20]
    assert db.search([2]).values.tolist() == [NOT_FOUND]
    assert db.range(0, 100) == [(1, 10), (3, 30)]
    assert len(db) == 2


def test_snapshot_context_releases_on_error():
    db = Uruv(CFG)
    db.insert([1], [10])
    with pytest.raises(RuntimeError, match="boom"):
        with db.snapshot() as ts:
            assert db.active_snapshots == 1
            assert db.range(0, 5, ts) == [(1, 10)]
            raise RuntimeError("boom")
    assert db.active_snapshots == 0


def test_snapshot_isolation_through_client():
    db = Uruv(CFG)
    db.insert(np.arange(20), np.arange(20) * 2)
    with db.snapshot() as ts:
        db.insert(np.arange(20), np.arange(20) * 100)
        old = db.range(0, 19, ts)
        assert old == [(k, 2 * k) for k in range(20)]
    db.compact()
    assert db.range(0, 19) == [(k, 100 * k) for k in range(20)]


def test_range_page_bounded_pass_resume():
    db = Uruv(CFG)
    db.insert(np.arange(100), np.arange(100))
    # max_results overflow on query 0 -> truncated + exact resume frontier
    page = db.range_page([0, 50], [99, 59], db.ts, max_results=16,
                         scan_leaves=16, max_rounds=1)
    assert isinstance(page, RangePage)
    cnt = np.asarray(page.count)
    assert int(cnt[1]) == 10 and not bool(np.asarray(page.truncated)[1])
    assert bool(np.asarray(page.truncated)[0])
    resume = int(np.asarray(page.resume_k1)[0])
    rest = db.range(resume, 99, db.ts)
    assert page.items(0) + rest == [(k, k) for k in range(100)]


# ---------------------------------------------------------------------------
# Deprecation shims: warning + bit-exact equivalence with the client path
# ---------------------------------------------------------------------------

def test_apply_updates_shim_warns_and_matches_client():
    from repro.core import batch as B, store as S

    rng = np.random.default_rng(1)
    keys = rng.integers(0, 50, 24).astype(np.int32)
    vals = rng.integers(0, 100, 24).astype(np.int32)
    vals[::4] = TOMBSTONE
    keys[5] = KEY_MAX

    st = S.create(CFG)
    with pytest.warns(DeprecationWarning, match="apply_updates"):
        st, prev = B.apply_updates(st, keys, vals)

    db = Uruv(CFG)
    res = db.apply(OpBatch.updates(keys, vals))
    np.testing.assert_array_equal(prev, np.asarray(res.values))
    assert S.live_items(st) == db.live_items()
    assert int(st.ts) == db.ts


def test_range_query_all_shim_warns_and_matches_client():
    from repro.core import batch as B, store as S

    db = Uruv(CFG)
    db.insert(np.arange(60), np.arange(60) * 3)
    st = db.store
    with pytest.warns(DeprecationWarning, match="range_query_all"):
        st, items = B.range_query_all(st, 5, 40, None)
    assert items == db.range(5, 40)
    # the shim registered AND released its snapshot through the client
    assert not bool(np.asarray(st.trk_active).any())


def test_bulk_update_shim_warns_and_matches_client():
    from repro.core import store as S

    rng = np.random.default_rng(2)
    # <= leaf_cap new keys per leaf: the raw pass must accept (ok=True) so
    # it stays comparable with the client path (which would slow-path)
    keys = np.arange(8, dtype=np.int32)
    vals = rng.integers(0, 100, 8).astype(np.int32)
    vals[::5] = TOMBSTONE

    st = S.create(CFG)
    with pytest.warns(DeprecationWarning, match="bulk_update"):
        st, prev, ok = S.bulk_update(st, keys, vals)
    assert bool(ok)

    db = Uruv(CFG)
    res = db.apply(OpBatch.updates(keys, vals))
    np.testing.assert_array_equal(np.asarray(prev), np.asarray(res.values))
    assert S.live_items(st) == db.live_items()
    assert int(st.ts) == db.ts


def test_internal_layers_raise_no_deprecation_warnings():
    """Engine/pipeline/checkpoint must be fully migrated: exercising them
    must not route through the deprecated entry points."""
    from repro.data.pipeline import StreamingSampleStore

    with warnings.catch_warnings():
        warnings.filterwarnings("error", category=DeprecationWarning,
                                module=r"repro(\..*)?")
        store = StreamingSampleStore(CFG)
        ids = np.arange(40, dtype=np.int32)
        store.ingest(ids, ids * 2)
        snap = store.epoch_view()
        assert store.read_shard(0, 39, snap) == [(int(i), int(i) * 2)
                                                 for i in ids]
        store.release(snap)
        store.retire_below(10)
        store.compact()
        assert store.live_count() == 30


# ---------------------------------------------------------------------------
# Layering gate: outside repro.core (and repro.api, which implements the
# facade), nothing imports core.store / core.batch / core.sharded — proven
# by uruvlint's AST import analysis (repro.analysis, DESIGN.md Sec 13),
# which replaced the old regex scan: it resolves relative imports and never
# trips on prose mentions in docstrings.
# ---------------------------------------------------------------------------

def test_layering_only_api_touches_core_internals():
    from repro.analysis.engine import run_paths
    from repro.analysis.rules import LayeringApiRule, LayeringIndexRule

    root = Path(__file__).resolve().parents[1]
    scan_dirs = [
        root / "src" / "repro", root / "benchmarks", root / "examples",
        root / "scripts",
    ]
    findings = run_paths(
        scan_dirs, rules=[LayeringApiRule(), LayeringIndexRule()], root=root)
    assert not findings, "layering violations:\n" + "\n".join(
        f.render() for f in findings)


# ---------------------------------------------------------------------------
# ShardedExecutor == LocalExecutor (4 fake devices, subprocess)
# ---------------------------------------------------------------------------

SHARDED_API_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "src")
import numpy as np
from repro.compat import make_mesh
from repro.api import OpBatch, ShardedConfig, Uruv, UruvConfig
from repro.core.ref import RefStore, OP_INSERT, OP_DELETE, OP_SEARCH, OP_RANGE

mesh = make_mesh((4,), ("data",))
base = UruvConfig(leaf_cap=8, max_leaves=128, max_versions=2048)
db = Uruv.sharded(ShardedConfig(base=base, key_lo=0, key_hi=400), mesh)
local = Uruv(base)
ref = RefStore()
rng = np.random.default_rng(11)

def check(batch, ops):
    r_sh = db.apply(batch)
    r_lo = local.apply(batch)
    want = ref.apply_batch(ops)
    assert r_sh.values.tolist() == r_lo.values.tolist() == want, (
        r_sh.values.tolist(), r_lo.values.tolist(), want)
    assert r_sh.pages() == r_lo.pages()
    assert db.ts == local.ts == ref.ts

for it in range(4):
    G = 16                       # divisible by 4: exercises the routed pass
    codes = rng.choice([OP_INSERT, OP_INSERT, OP_DELETE, OP_SEARCH],
                       G).astype(np.int32)
    keys = rng.integers(0, 400, G).astype(np.int32)
    vals = rng.integers(0, 1000, G).astype(np.int32)
    check(OpBatch(codes, keys, vals),
          [(int(c), int(k), int(v)) for c, k, v in zip(codes, keys, vals)])

# mixed plan with RANGE segments through the same client surface
plan = OpBatch.concat(
    OpBatch.ranges([0, 100], [99, 399]),
    OpBatch.inserts([5], [55]),
    OpBatch.ranges([0], [9]),
)
check(plan, [(OP_RANGE, 0, 99), (OP_RANGE, 100, 399),
             (OP_INSERT, 5, 55), (OP_RANGE, 0, 9)])

assert db.live_items() == local.live_items() == ref.live_items()
assert db.lookup(np.arange(0, 400, 7)).tolist() == \
    local.lookup(np.arange(0, 400, 7)).tolist()
with db.snapshot() as s1, local.snapshot() as s2:
    assert s1 == s2
    assert db.range_all([0, 50], [399, 250], s1) == \
        local.range_all([0, 50], [399, 250], s2)
assert db.active_snapshots == 0
print("SHARDED_API_OK")
"""


def test_sharded_executor_matches_local_subprocess():
    proc = subprocess.run(
        [sys.executable, "-c", SHARDED_API_SCRIPT],
        cwd=Path(__file__).resolve().parents[1],
        capture_output=True, text=True, timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "SHARDED_API_OK" in proc.stdout
