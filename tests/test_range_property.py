"""Range-search + snapshot correctness battery for `store.bulk_range`.

Covers the PR-2 contract (DESIGN.md Sec 8):

  * oracle equivalence — random interleavings of `bulk_apply` batches and
    `bulk_range` query arrays against `core.ref.RefStore.range_query`,
    including tombstoned keys, duplicate keys across pages, and
    empty/inverted (k1 > k2) intervals.  Hypothesis drives the search when
    available; a seeded numpy sweep of the same generators always runs so
    the battery never goes dark in containers without hypothesis.
  * snapshot isolation — a registered snapshot's results are byte-identical
    across later updates AND compaction; tracker register/release
    accounting (min_active_ts, OFLOW_TRACKER) is asserted.
  * pagination/truncation edges — the resume-from-`resume_k1` contract
    (page ends with cnt == 0, exactly == max_results hits, window closing
    one leaf before k2) that `range_query_all` relied on pre-rewrite.
  * one-pass guard — Q=256 mixed-width intervals answered with exactly one
    jitted device pass (no host sync between queries).
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import batch as B
from repro.core import store as S
from repro.core.ref import (
    KEY_MAX, NOT_FOUND, TOMBSTONE,
    OP_DELETE, OP_INSERT, OP_NOP, OP_RANGE, OP_SEARCH, RefStore,
)

try:
    from hypothesis import HealthCheck, given, settings, strategies as hst
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False

CFG = S.UruvConfig(leaf_cap=8, max_leaves=512, max_versions=1 << 14,
                   max_chain=32, tracker_cap=8)
KEYSPACE = 120


def _build(history):
    """Apply a history of op batches to both the store and the oracle."""
    st = S.create(CFG)
    ref = RefStore()
    for ops in history:
        st, res = B.apply_batch(st, ops)
        assert res == ref.apply_batch(ops)
    return st, ref


def _check_queries(st, ref, intervals, snap_ts, **budgets):
    """bulk_range over all intervals at once == oracle per interval."""
    k1 = np.array([a for a, _ in intervals], np.int32)
    k2 = np.array([b for _, b in intervals], np.int32)
    pages = B.bulk_range_all(st, k1, k2, snap_ts, **budgets)
    for q, (a, b) in enumerate(intervals):
        want = ref.range_query(int(a), int(b), int(snap_ts))
        assert pages[q] == want, (q, a, b, pages[q][:4], want[:4])


def _random_history(rng, n_batches):
    history = []
    for _ in range(n_batches):
        n = int(rng.integers(1, 24))
        codes = rng.choice(
            [OP_INSERT, OP_INSERT, OP_INSERT, OP_DELETE, OP_SEARCH, OP_NOP], n
        )
        history.append([
            (int(c), int(rng.integers(0, KEYSPACE)), int(rng.integers(0, 1000)))
            for c in codes
        ])
    return history


def _random_intervals(rng, q):
    out = []
    for _ in range(q):
        a, b = int(rng.integers(0, KEYSPACE)), int(rng.integers(0, KEYSPACE))
        if rng.random() < 0.8 and a > b:
            a, b = b, a                       # keep ~20% inverted intervals
        out.append((a, b))
    return out


# ---------------------------------------------------------------------------
# oracle equivalence (always-on seeded sweep)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_random_interleaving_vs_oracle(seed):
    """Interleave update batches with bulk_range arrays; every interleaving
    point must match the oracle at the CURRENT clock (tombstones dropped,
    duplicates collapse to the per-snapshot resolved version)."""
    rng = np.random.default_rng(seed)
    st = S.create(CFG)
    ref = RefStore()
    for it in range(5):
        for ops in _random_history(rng, 2):
            st, res = B.apply_batch(st, ops)
            assert res == ref.apply_batch(ops)
        _check_queries(st, ref, _random_intervals(rng, 8), int(st.ts),
                       max_results=16, scan_leaves=2, max_rounds=2)
    S.check_invariants(st)


def test_duplicate_keys_across_pages_and_tombstones():
    """Overwritten + tombstoned keys spread across many pages: pagination
    must not duplicate or resurrect anything."""
    st = S.create(CFG)
    ref = RefStore()
    keys = np.arange(0, 100, dtype=np.int32)
    for _ in range(3):                        # 3 generations of overwrites
        for i in range(0, 100, 16):
            ops = [(OP_INSERT, int(k), int(k * 7 % 91)) for k in keys[i:i+16]]
            st, _ = B.apply_batch(st, ops)
            ref.apply_batch(ops)
    dels = [(OP_DELETE, int(k), 0) for k in keys[::3]]
    st, _ = B.apply_batch(st, dels)
    ref.apply_batch(dels)
    # tiny page budget forces many resume rounds over the duplicate chains
    _check_queries(st, ref, [(0, 99), (10, 11), (33, 32)], int(st.ts),
                   max_results=4, scan_leaves=1, max_rounds=1)


def test_empty_and_inverted_intervals():
    st = S.create(CFG)
    ref = RefStore()
    ops = [(OP_INSERT, k, k) for k in (10, 20, 30)]
    st, _ = B.apply_batch(st, ops)
    ref.apply_batch(ops)
    intervals = [(31, 9), (11, 19), (0, 9), (21, 29), (30, 10), (15, 15)]
    _check_queries(st, ref, intervals, int(st.ts))
    # device-level flags: empty/inverted queries are complete, not truncated
    k1 = np.array([a for a, _ in intervals], np.int32)
    k2 = np.array([b for _, b in intervals], np.int32)
    _, _, cnt, trunc, _ = S.bulk_range(st, k1, k2, int(st.ts))
    assert np.asarray(cnt).tolist() == [0, 0, 0, 0, 0, 0]
    assert not np.asarray(trunc).any()


def test_mixed_announce_with_op_range_vs_oracle():
    """RANGEQUERY rides the mixed announce array: op i's count reflects
    exactly the in-batch ops before it (per-op snapshot = base + i)."""
    st = S.create(CFG)
    ref = RefStore()
    ops = [
        (OP_RANGE, 0, 50, ),
        (OP_INSERT, 10, 1),
        (OP_INSERT, 20, 2),
        (OP_RANGE, 0, 50),        # sees 10 and 20
        (OP_DELETE, 10, 0),
        (OP_RANGE, 0, 50),        # 20 only
        (OP_RANGE, 50, 0),        # inverted -> 0
        (OP_INSERT, 10, 3),
        (OP_RANGE, 0, 50),        # 10 back
        (OP_SEARCH, 10, 0),
    ]
    st, res = B.apply_batch(st, ops)
    assert res == ref.apply_batch(ops)
    assert res[0] == 0 and res[3] == 2 and res[5] == 1
    assert res[6] == 0 and res[8] == 2 and res[9] == 3
    assert int(st.ts) == ref.ts


# ---------------------------------------------------------------------------
# hypothesis battery (skipped where hypothesis is unavailable)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    op_st = hst.tuples(
        hst.sampled_from(
            [OP_INSERT, OP_INSERT, OP_INSERT, OP_DELETE, OP_SEARCH, OP_RANGE]
        ),
        hst.integers(0, KEYSPACE - 1),
        hst.integers(0, KEYSPACE - 1),
    )
    batch_st = hst.lists(op_st, min_size=1, max_size=20)
    history_st = hst.lists(batch_st, min_size=1, max_size=5)
    interval_st = hst.lists(
        hst.tuples(hst.integers(0, KEYSPACE - 1),
                   hst.integers(0, KEYSPACE - 1)),
        min_size=1, max_size=8,
    )
    HSET = settings(
        max_examples=25, deadline=None,
        suppress_health_check=[HealthCheck.too_slow,
                               HealthCheck.data_too_large],
    )

    @given(history_st, interval_st)
    @HSET
    def test_hypothesis_history_then_bulk_range(history, intervals):
        st, ref = _build(history)
        _check_queries(st, ref, intervals, int(st.ts),
                       max_results=8, scan_leaves=1, max_rounds=2)

    @given(history_st, hst.integers(0, 4), interval_st)
    @HSET
    def test_hypothesis_snapshot_stability(history, snap_after, intervals):
        """A registered snapshot's bulk_range answers never change across
        arbitrary later batches."""
        st = S.create(CFG)
        ref = RefStore()
        snap = want = None
        for i, ops in enumerate(history):
            if i == min(snap_after, len(history) - 1) and snap is None:
                st, ts = S.snapshot(st)
                snap = int(ts)
                assert snap == ref.snapshot()
                want = [ref.range_query(min(a, b), max(a, b), snap)
                        for a, b in intervals]
            st, _ = B.apply_batch(st, ops)
            ref.apply_batch(ops)
        if snap is not None:
            k1 = np.array([min(a, b) for a, b in intervals], np.int32)
            k2 = np.array([max(a, b) for a, b in intervals], np.int32)
            got = B.bulk_range_all(st, k1, k2, snap,
                                   max_results=8, scan_leaves=1, max_rounds=2)
            assert got == want


# ---------------------------------------------------------------------------
# snapshot isolation + tracker accounting
# ---------------------------------------------------------------------------

def test_snapshot_results_byte_identical_across_updates():
    st = S.create(CFG)
    ref = RefStore()
    ops = [(OP_INSERT, k, k * 2) for k in range(0, 60, 2)]
    st, _ = B.apply_batch(st, ops)
    ref.apply_batch(ops)
    st, snap = S.snapshot(st)
    rsnap = ref.snapshot()
    assert int(snap) == rsnap
    k1 = np.array([0, 10, 30], np.int32)
    k2 = np.array([59, 20, 31], np.int32)
    before = S.bulk_range(st, k1, k2, int(snap), max_results=64)
    before_np = [np.asarray(x).copy() for x in before]
    # mutate heavily: overwrites, deletes, new keys (structural churn)
    for gen in range(3):
        ops = ([(OP_INSERT, k, 999 - k) for k in range(0, 60, 2)]
               + [(OP_DELETE, k, 0) for k in range(0, 60, 8)]
               + [(OP_INSERT, k, gen) for k in range(61, 90, 2)])
        st, _ = B.apply_batch(st, ops)
        ref.apply_batch(ops)
    after = S.bulk_range(st, k1, k2, int(snap), max_results=64)
    for b, a in zip(before_np, after):
        np.testing.assert_array_equal(b, np.asarray(a))   # byte-identical
    # and both equal the oracle at the snapshot
    pages = B.bulk_range_all(st, k1, k2, int(snap))
    for q in range(3):
        assert pages[q] == ref.range_query(int(k1[q]), int(k2[q]), rsnap)
    # the CURRENT clock sees the new world
    now = B.bulk_range_all(st, k1, k2, int(st.ts))
    assert now[0] == ref.range_query(0, 59, ref.ts)
    assert now[0] != pages[0]
    st = S.release(st, int(snap))
    ref.release(rsnap)


def test_tracker_accounting_and_oflow_ring_exhaustion():
    st = S.create(CFG)                        # tracker_cap = 8
    base_ts = int(st.ts)
    snaps = []
    for i in range(CFG.tracker_cap):
        st, s = S.snapshot(st)
        snaps.append(int(s))
        assert int(S.min_active_ts(st)) == snaps[0]
        assert int(st.oflow) & S.OFLOW_TRACKER == 0
    # ring full: the next registration EVICTS the oldest entry and flags it
    # (OFLOW_TRACKER == "a snapshot lost its GC protection")
    st, s_over = S.snapshot(st)
    assert int(st.oflow) & S.OFLOW_TRACKER
    assert int(S.min_active_ts(st)) == snaps[1]     # snaps[0] unprotected
    st = S.release(st, snaps[0])                    # evicted: a no-op
    assert int(S.min_active_ts(st)) == snaps[1]
    # release in FIFO order advances min_active_ts exactly
    for i, s in enumerate(snaps[1:-1], start=1):
        st = S.release(st, s)
        assert int(S.min_active_ts(st)) == snaps[i + 1]
    st = S.release(st, snaps[-1])
    assert int(S.min_active_ts(st)) == int(s_over)
    st = S.release(st, int(s_over))
    assert int(S.min_active_ts(st)) == int(st.ts)   # nothing active
    assert int(st.ts) == base_ts + CFG.tracker_cap + 1


def test_compact_never_reclaims_live_snapshot_versions():
    st = S.create(CFG)
    ref = RefStore()
    ops = [(OP_INSERT, k, k + 100) for k in range(40)]
    st, _ = B.apply_batch(st, ops)
    ref.apply_batch(ops)
    st, snap = S.snapshot(st)
    rsnap = ref.snapshot()
    want = ref.range_query(0, 39, rsnap)
    # overwrite everything + delete half AFTER the snapshot, then compact:
    # the tracker floor (== snap) must retain the snapshot-visible versions
    ops = ([(OP_INSERT, k, 0) for k in range(40)]
           + [(OP_DELETE, k, 0) for k in range(0, 40, 2)])
    st, _ = B.apply_batch(st, ops)
    ref.apply_batch(ops)
    assert int(S.min_active_ts(st)) == int(snap)
    st, n_live = S.compact(st)
    got = B.bulk_range_all(st, [0], [39], int(snap))[0]
    assert got == want, "compact reclaimed versions a live snapshot reads"
    S.check_invariants(st)
    # release, compact again: now the old versions are reclaimable and the
    # snapshot view legitimately disappears
    st = S.release(st, int(snap))
    n_before = int(st.n_vers)
    st, _ = S.compact(st)
    assert int(st.n_vers) < n_before
    now = B.bulk_range_all(st, [0], [39], int(st.ts))[0]
    assert now == ref.range_query(0, 39, ref.ts)


# ---------------------------------------------------------------------------
# pagination / truncation edges (the pre-rewrite `pragma: no cover` branch)
# ---------------------------------------------------------------------------

def _dense_store(n=200, leaf_cap=8):
    cfg = S.UruvConfig(leaf_cap=leaf_cap, max_leaves=256,
                       max_versions=1 << 14, max_chain=16)
    st = S.create(cfg)
    ref = RefStore()
    keys = np.arange(0, n, dtype=np.int32)
    for i in range(0, n, 16):
        ops = [(OP_INSERT, int(k), int(k) * 3) for k in keys[i:i+16]]
        st, _ = B.apply_batch(st, ops)
        ref.apply_batch(ops)
    return st, ref


def test_page_ends_with_zero_hits_still_progresses():
    """A window whose leaves hold NO in-interval keys (cnt == 0, truncated)
    must resume past the scanned leaves, not stall or skip."""
    st, ref = _dense_store()
    # delete a long prefix of the interval so early pages are all-tombstone
    dels = [(OP_DELETE, k, 0) for k in range(10, 120)]
    st, _ = B.apply_batch(st, dels)
    ref.apply_batch(dels)
    ts = int(st.ts)
    k, v, cnt, trunc, resume = S.bulk_range(
        st, np.array([10], np.int32), np.array([150], np.int32), ts,
        max_results=64, scan_leaves=1, max_rounds=1,
    )
    assert int(cnt[0]) == 0 and bool(trunc[0])         # the cnt==0 page
    assert int(resume[0]) > 10                          # progressed by leaves
    got = B.bulk_range_all(st, [10], [150], ts,
                           max_results=64, scan_leaves=1, max_rounds=1)[0]
    assert got == ref.range_query(10, 150, ref.ts)


def test_page_hits_exactly_max_results():
    """cnt == max_results with the window already closed: NOT truncated;
    with more interval left: truncated and resumable."""
    st, ref = _dense_store(n=64)
    ts = int(st.ts)
    # exactly 8 hits in [0, 7], window closes within budget -> complete page
    k, v, cnt, trunc, resume = S.bulk_range(
        st, np.array([0], np.int32), np.array([7], np.int32), ts,
        max_results=8, scan_leaves=4, max_rounds=4,
    )
    assert int(cnt[0]) == 8 and not bool(trunc[0])
    # 8 hits fill the block but [0, 20] has more -> truncated, resume = 8
    k, v, cnt, trunc, resume = S.bulk_range(
        st, np.array([0], np.int32), np.array([20], np.int32), ts,
        max_results=8, scan_leaves=4, max_rounds=4,
    )
    assert int(cnt[0]) == 8 and bool(trunc[0])
    assert int(resume[0]) == int(np.asarray(k)[0, 7]) + 1
    got = B.bulk_range_all(st, [0], [20], ts, max_results=8)[0]
    assert got == ref.range_query(0, 20, ref.ts)


def test_window_closes_one_leaf_before_k2():
    """The scan window ends exactly one leaf short of k2: truncated with
    resume at the first unscanned separator (no key skipped/duplicated)."""
    st, ref = _dense_store(n=64, leaf_cap=8)
    ts = int(st.ts)
    seps, _ = S.directory(st)
    n_leaves = int(st.n_leaves)
    assert n_leaves >= 4
    # k2 = last key of leaf 2; scan budget covers leaves 0..1 only
    k2 = int(seps[3]) - 1
    k, v, cnt, trunc, resume = S.bulk_range(
        st, np.array([0], np.int32), np.array([k2], np.int32), ts,
        max_results=64, scan_leaves=1, max_rounds=2,
    )
    assert bool(trunc[0])
    assert int(resume[0]) == int(seps[2])
    ks = np.asarray(k)[0, :int(cnt[0])]
    assert ks.max() < int(resume[0])
    got = B.bulk_range_all(st, [0], [k2], ts,
                           max_results=64, scan_leaves=1, max_rounds=2)[0]
    assert got == ref.range_query(0, k2, ref.ts)


def test_legacy_range_query_all_contract_preserved():
    """The rewritten range_query_all keeps the seed contract: complete
    coverage under tiny budgets + snapshot register/release when snap_ts
    is None."""
    st, ref = _dense_store()
    st, got = B.range_query_all(st, 5, 180, None, max_scan_leaves=2,
                                max_results=16)
    rsnap = ref.snapshot()
    ref.release(rsnap)
    assert got == ref.range_query(5, 180, rsnap)
    assert int(st.ts) == ref.ts                 # the None path advanced ts
    assert not bool(np.asarray(st.trk_active).any())   # and released it


def test_op_range_exact_past_max_chain_in_batch():
    """A range op whose keys gain >= max_chain versions LATER in the same
    announce array must still count them (segment execution resolves the
    range before those versions exist; post-hoc resolution would walk past
    the chain bound and silently drop keys)."""
    cfg = S.UruvConfig(leaf_cap=8, max_leaves=256, max_versions=1 << 14,
                       max_chain=8)
    st = S.create(cfg)
    ref = RefStore()
    seed = [(OP_INSERT, k, k) for k in range(8)]
    st, _ = B.apply_batch(st, seed)
    ref.apply_batch(seed)
    ops = [(OP_RANGE, 0, 7, )]
    for gen in range(cfg.max_chain + 3):      # 11 generations > max_chain
        ops += [(OP_INSERT, k, gen) for k in range(8)]
    ops.append((OP_RANGE, 0, 7))
    st, res = B.apply_batch(st, ops)
    rres = ref.apply_batch(ops)
    assert res == rres
    assert res[0] == 8 and res[-1] == 8
    # and the mirror case: > max_chain same-key updates BEFORE the range
    # op in one batch (the range reads the freshest version at depth 0)
    ops2 = [(OP_INSERT, 3, g) for g in range(cfg.max_chain + 5)]
    ops2.append((OP_RANGE, 3, 3))
    ops2.append((OP_SEARCH, 3, 0))
    st, res2 = B.apply_batch(st, ops2)
    assert res2 == ref.apply_batch(ops2)
    assert res2[-2] == 1 and res2[-1] == cfg.max_chain + 4
    assert int(st.ts) == ref.ts


def test_sharded_apply_batch_rejects_op_range():
    """store.bulk_apply treats unknown codes as NOP, so the sharded CRUD
    helper must refuse OP_RANGE loudly instead of silently NOPing it
    (range announce arrays go through make_range_apply)."""
    from repro.core import sharded as SH

    with pytest.raises(ValueError, match="make_range_apply"):
        SH.sharded_apply_batch(
            None, np.array([OP_RANGE], np.int32), np.array([5], np.int32),
            np.array([9], np.int32), apply_fn=None,
        )


def test_pipeline_read_shards_one_consistent_epoch():
    """All epoch readers' shard ranges resolve in one batched pass at one
    snapshot: concurrent ingest never leaks into the epoch, and the shards
    tile the keyspace exactly."""
    from repro.data.pipeline import StreamingSampleStore

    store = StreamingSampleStore(CFG)
    ids = np.arange(100, dtype=np.int32)
    store.ingest(ids, ids * 10)
    snap = store.epoch_view()
    bounds = [(0, 24), (25, 49), (50, 74), (75, 99)]
    views = store.read_shards(bounds, snap)
    # later ingest must not appear in the epoch views
    store.ingest(np.arange(100, 140, dtype=np.int32), np.zeros(40, np.int32))
    views2 = store.read_shards(bounds, snap)
    assert views == views2
    flat = [kv for view in views for kv in view]
    assert flat == [(int(i), int(i) * 10) for i in ids]
    store.release(snap)


# ---------------------------------------------------------------------------
# one-pass guard: Q=256 in a single jitted device call
# ---------------------------------------------------------------------------

def test_q256_single_device_pass(monkeypatch):
    """256 mixed-width intervals must be answered by exactly ONE
    _bulk_range device call (no host sync / per-query dispatch)."""
    st, ref = _dense_store(n=200)
    rng = np.random.default_rng(9)
    lo = rng.integers(0, 200, 256).astype(np.int32)
    width = rng.choice([0, 1, 5, 20, 80], 256)
    hi = np.minimum(lo + width, 210).astype(np.int32)
    calls = {"n": 0}
    orig = S._bulk_range
    monkeypatch.setattr(
        S, "_bulk_range",
        lambda *a, **kw: (calls.__setitem__("n", calls["n"] + 1),
                          orig(*a, **kw))[1],
    )
    ts = int(st.ts)
    pages = B.bulk_range_all(st, lo, hi, ts,
                             max_results=256, scan_leaves=8, max_rounds=8)
    assert calls["n"] == 1, "Q=256 took more than one device pass"
    for q in range(256):
        assert pages[q] == ref.range_query(int(lo[q]), int(hi[q]), ref.ts)
