"""Checkpoint/restore, crash-restart determinism, elastic reshard,
straggler monitor, gradient compression."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.config import get_arch
from repro.data.pipeline import make_batch
from repro.distributed.fault import StragglerMonitor, reshard, run_with_restarts
from repro.optim import adamw
from repro.optim.compression import (
    CompressionConfig, compress_grads, init_error,
)
from repro.train import steps


def tiny_cfg():
    return get_arch("llama3_2_1b").reduced()


def test_checkpoint_roundtrip_bitexact(tmp_path):
    cfg = tiny_cfg()
    state = steps.init_state(cfg, jax.random.key(0))
    mgr = CheckpointManager(tmp_path, keep=2, async_write=False)
    mgr.save(state, 7)
    like = jax.eval_shape(lambda: steps.init_state(cfg, jax.random.key(0)))
    restored, step = mgr.restore(like)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_keeps_last_k(tmp_path):
    cfg = tiny_cfg()
    state = steps.init_state(cfg, jax.random.key(0))
    mgr = CheckpointManager(tmp_path, keep=2, async_write=False)
    for s in (1, 2, 3, 4):
        mgr.save(state, s)
    assert mgr.latest_step() == 4
    kept = sorted(p.name for p in tmp_path.glob("step_*"))
    assert kept == ["step_00000003", "step_00000004"]


def test_crash_restart_is_deterministic(tmp_path):
    """Crash mid-training; the restarted run replays to the same trajectory."""
    cfg = tiny_cfg()
    opt = adamw.AdamWConfig()
    step_fn = jax.jit(steps.make_train_step(cfg, opt))

    def init_fn():
        return steps.init_state(cfg, jax.random.key(0))

    def batch_fn(step):
        return make_batch(cfg, 2, 16, step)

    # run A: no crash
    mgr_a = CheckpointManager(tmp_path / "a", keep=3, async_write=False)
    state_a, hist_a = run_with_restarts(
        init_fn=init_fn, step_fn=step_fn, batch_fn=batch_fn,
        ckpt=mgr_a, total_steps=12, ckpt_every=4)
    # run B: crashes at steps 6 and 10
    mgr_b = CheckpointManager(tmp_path / "b", keep=3, async_write=False)
    state_b, hist_b = run_with_restarts(
        init_fn=init_fn, step_fn=step_fn, batch_fn=batch_fn,
        ckpt=mgr_b, total_steps=12, ckpt_every=4, crash_at=[6, 10])
    assert any(h[0] == "restart" for h in hist_b)
    losses_a = {s: l for k, s, l in hist_a if k == "step"}
    losses_b = {s: l for k, s, l in hist_b if k == "step"}
    for s in losses_a:
        np.testing.assert_allclose(losses_a[s], losses_b[s], rtol=1e-5)
    for a, b in zip(jax.tree.leaves(state_a.params),
                    jax.tree.leaves(state_b.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_elastic_restore_new_sharding(tmp_path):
    """Save, then restore onto explicit (single-device) shardings — the
    elastic path; multi-device resharding is proven by the dry-run meshes."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh

    cfg = tiny_cfg()
    state = steps.init_state(cfg, jax.random.key(0))
    mgr = CheckpointManager(tmp_path, async_write=True)
    mgr.save(state, 3)
    mgr.wait()
    mesh = make_host_mesh(1, 1)
    like = jax.eval_shape(lambda: steps.init_state(cfg, jax.random.key(0)))
    shardings = jax.tree.map(
        lambda _: NamedSharding(mesh, P()), like)
    restored, step = mgr.restore(like, shardings=shardings)
    assert step == 3
    moved = reshard(restored, shardings)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(moved)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_straggler_monitor_detects_outlier():
    mon = StragglerMonitor(factor=2.0, min_samples=4)
    hits = []
    mon.on_straggler(lambda ev: hits.append(ev))
    for s in range(10):
        mon.record(s, 0.10 + 0.001 * s)
    ev = mon.record(10, 0.50)
    assert ev is not None and hits and hits[0].factor > 2.0
    assert mon.record(11, 0.11) is None


@pytest.mark.parametrize("kind", ["topk", "int8"])
def test_compression_error_feedback(kind):
    cfg = CompressionConfig(kind=kind, topk_ratio=0.25)
    params = {"w": jnp.zeros((32, 32))}
    err = init_error(params)
    rng = np.random.default_rng(0)
    true_sum = np.zeros((32, 32), np.float32)
    sent_sum = np.zeros((32, 32), np.float32)
    for _ in range(50):
        g = {"w": jnp.asarray(rng.standard_normal((32, 32)), jnp.float32)}
        sent, err = compress_grads(cfg, g, err)
        true_sum += np.asarray(g["w"])
        sent_sum += np.asarray(sent["w"])
    # telescoping identity: cumulative(true) - cumulative(sent) == error buf
    resid = true_sum - sent_sum
    np.testing.assert_allclose(resid, np.asarray(err["w"]),
                               atol=1e-4, rtol=1e-3)
    # and the residual stays bounded (EF does not diverge)
    assert np.abs(resid).max() < (3.0 if kind == "topk" else 0.05)


def test_compressed_psum_single_axis():
    from repro.compat import shard_map
    from repro.optim.compression import compressed_psum

    mesh = jax.make_mesh((1,), ("data",))
    g = jnp.asarray(np.random.default_rng(0).standard_normal((64,)),
                    jnp.float32)
    out = jax.jit(
        shard_map(
            lambda x: compressed_psum(x, "data"), mesh=mesh,
            in_specs=jax.sharding.PartitionSpec(),
            out_specs=jax.sharding.PartitionSpec(),
        )
    )(g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(g),
                               atol=2e-2)
