"""Shared deterministic workload for the durability battery.

Imported by tests/test_wal_recovery.py AND executed as the crash-injected
subprocess worker (``python -c "import _wal_workload; _wal_workload.worker_main()"``
with ``PYTHONPATH`` including this directory).  Everything here is a pure
function of the seed — the parent process rebuilds the exact plan stream
the killed worker was applying and replays it on a volatile oracle.
"""

from __future__ import annotations

import json
import os
from typing import Dict, List

import numpy as np

from repro.api import LifecyclePolicy, OpBatch, Uruv, UruvConfig
from repro.core.ref import (
    KEY_MAX, OP_DELETE, OP_INSERT, OP_NOP, OP_RANGE, OP_SEARCH, RefStore,
)

KEYSPACE = 120
PROBE_KEYS = list(range(0, KEYSPACE, 3))

# op-code mix per plan slot: (insert, delete, search, range)
MIXES: Dict[str, tuple] = {
    "update": (0.60, 0.25, 0.10, 0.05),
    "read": (0.30, 0.05, 0.45, 0.20),
    "range": (0.35, 0.10, 0.10, 0.45),
}


def small_config() -> UruvConfig:
    """Small enough that the battery workloads cross grow() boundaries."""
    return UruvConfig(leaf_cap=8, max_leaves=16, max_versions=128,
                      tracker_cap=8)


def policy(maintain: bool) -> LifecyclePolicy:
    """auto_grow always (growth boundaries are battery targets);
    auto_maintain only for the result-level cases, and version GC off
    (version_gc_fraction > 1 means capacity pressure always grows the
    pool) — maintenance and compaction may reclaim versions below the
    snapshot floor, so full historical-replay equality against RefStore
    (which never reclaims) needs both off."""
    return LifecyclePolicy(auto_grow=True, auto_maintain=maintain,
                           version_gc_fraction=2.0)


def make_plans(seed: int, n_plans: int, width: int,
               mix: str) -> List[OpBatch]:
    rng = np.random.default_rng(seed)
    p = MIXES[mix]
    plans = []
    for _ in range(n_plans):
        r = rng.random(width)
        codes = np.full(width, OP_SEARCH, np.int32)
        codes[r < p[0]] = OP_INSERT
        codes[(r >= p[0]) & (r < p[0] + p[1])] = OP_DELETE
        codes[r >= 1.0 - p[3]] = OP_RANGE
        keys = rng.integers(0, KEYSPACE, width).astype(np.int32)
        values = np.where(
            codes == OP_INSERT,
            rng.integers(1, 100000, width), 0).astype(np.int32)
        is_rng = codes == OP_RANGE
        values[is_rng] = keys[is_rng] + rng.integers(0, 30, width)[is_rng]
        plans.append(OpBatch(codes, keys, values))
    return plans


# ---------------------------------------------------------------------------
# result-level summaries (what recovered must match)
# ---------------------------------------------------------------------------

def sample_ts(ts: int) -> List[int]:
    step = max(1, ts // 16)
    return sorted(set(list(range(0, ts + 1, step)) + [ts]))


def summarize(db: Uruv, *, historical: bool = True) -> dict:
    """Result-level fingerprint: live items, probe lookups at the current
    clock, and (``historical``) probe lookups at sampled past snapshots —
    equal lookups at two clock values pin the version timestamps between
    them, so matching fingerprints mean bit-identical values AND version
    timestamps, not just a matching final state."""
    ts = db.ts
    out = {
        "ts": ts,
        "items": [[int(k), int(v)] for k, v in db.live_items()],
        "now": db.lookup(PROBE_KEYS, ts).tolist(),
    }
    if historical:
        out["hist"] = [[t, db.lookup(PROBE_KEYS, t).tolist()]
                       for t in sample_ts(ts)]
    return out


def ref_summary(plans: List[OpBatch], n_applied: int, *,
                historical: bool = True) -> dict:
    """The same fingerprint computed by the pure-python RefStore replay."""
    ref = RefStore()
    for plan in plans[:n_applied]:
        ref.apply_batch(list(zip(np.asarray(plan.codes).tolist(),
                                 np.asarray(plan.keys).tolist(),
                                 np.asarray(plan.values).tolist())))
    ts = ref.ts
    out = {
        "ts": ts,
        "items": [[k, v] for k, v in ref.range_query(0, KEY_MAX - 2, ts)],
        "now": [ref.search_at(k, ts) for k in PROBE_KEYS],
    }
    if historical:
        out["hist"] = [[t, [ref.search_at(k, t) for k in PROBE_KEYS]]
                       for t in sample_ts(ts)]
    return out


# ---------------------------------------------------------------------------
# the crash-injected worker
# ---------------------------------------------------------------------------

def ack_path(durable_dir: str) -> str:
    return os.path.join(durable_dir, "acked")


def read_acked(durable_dir: str) -> int:
    try:
        with open(ack_path(durable_dir)) as f:
            return int(f.read())
    except FileNotFoundError:
        return 0


def _ack(durable_dir: str, n: int) -> None:
    tmp = ack_path(durable_dir) + ".tmp"
    with open(tmp, "w") as f:
        f.write(str(n))
    os.replace(tmp, ack_path(durable_dir))


def worker_main() -> None:
    """Apply the seeded plan stream against a durable client, acking each
    confirmed plan; dies by SIGKILL wherever ``URUV_CRASH_POINT`` says.
    Resumes via ``Uruv.recover`` when the directory already has history
    (the clock is the plan cursor: every plan has one fixed width)."""
    d = os.environ["URUV_W_DIR"]
    seed = int(os.environ["URUV_W_SEED"])
    n_plans = int(os.environ["URUV_W_PLANS"])
    width = int(os.environ["URUV_W_WIDTH"])
    mix = os.environ["URUV_W_MIX"]
    ckpt_every = int(os.environ.get("URUV_W_CKPT", "0"))
    maintain = os.environ.get("URUV_W_MAINTAIN", "0") == "1"
    maintain_every = int(os.environ.get("URUV_W_MAINTAIN_EVERY", "0"))

    plans = make_plans(seed, n_plans, width, mix)
    if os.path.exists(os.path.join(d, "uruv.json")):
        db = Uruv.recover(d, policy=policy(maintain))
    else:
        db = Uruv(small_config(), durable_dir=d, policy=policy(maintain))
    assert db.ts % width == 0, (db.ts, width)
    for i in range(db.ts // width, n_plans):
        db.apply(plans[i])
        _ack(d, i + 1)
        if ckpt_every and (i + 1) % ckpt_every == 0:
            db.checkpoint()
        if maintain_every and (i + 1) % maintain_every == 0:
            db.maintain()
    db.durability.close()
    print(json.dumps({"done": True, "ts": db.ts}))


if __name__ == "__main__":
    worker_main()
