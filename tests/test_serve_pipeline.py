"""Serving front end (ISSUE 7): pipelined apply_nowait/confirm, the
coalescer's bit-exact future semantics, sentinel-key regressions, and the
deep admission queue.

The coalescer property test is the load-bearing one: per-client results
under skewed bursty closed-loop load must be BIT-EXACT (values, found,
timestamps, range pages) against replaying the coalescer's own dispatch
log through a synchronous client — pipelining, speculation, rejection
replay, and future slicing must all be invisible in results.
"""

import collections

import numpy as np
import pytest

from repro.api import (
    KEY_MAX, NOT_FOUND, OpBatch, Uruv, UruvConfig,
)
from repro.serve.coalescer import AdmissionPolicy, Coalescer, OpFuture
from repro.serve.engine import prefix_hash

CFG = UruvConfig(leaf_cap=8, max_leaves=512, max_versions=1 << 14,
                 max_chain=16)


# --------------------------------------------------------------- sentinels
def test_prefix_hash_never_emits_sentinel_keys():
    """Regression for the sentinel-key silent-loss bug: DEMONSTRABLY FAILS
    on the pre-fix ``prefix_hash`` (``& 0x7FFFFFFF`` then ``or 1``).

    The adversarial token below makes the pre-fix single-token hash land
    exactly on ``0x7FFFFFFF`` = 2**31 - 1 = KEY_MAX, the padding sentinel:
    the store accepts the INSERT and ``lookup`` then never finds it, so
    the cached prefix is silently lost forever.  The fixed hash clamps
    into [1, 2**31 - 4] — always a valid, findable key.
    """
    fnv, mul = 2166136261, 16777619
    t_keymax = (0x7FFFFFFF - 1 - fnv * mul) % (2 ** 31)
    t_pad = (0x7FFFFFFE - 1 - fnv * mul) % (2 ** 31)
    # pre-fix: ((fnv * mul + t + 1) & 0x7FFFFFFF) == the two sentinels
    assert (fnv * mul + t_keymax + 1) & 0x7FFFFFFF == KEY_MAX
    assert (fnv * mul + t_pad + 1) & 0x7FFFFFFF == KEY_MAX - 1

    for tokens in ([t_keymax], [t_pad], [0], [1, 2, 3], list(range(64))):
        h = prefix_hash(tokens)
        assert 1 <= h <= 2 ** 31 - 4, (tokens, h)

    # end-to-end: the adversarial prefix round-trips through the table
    db = Uruv(CFG)
    for t in ([t_keymax], [t_pad]):
        k = prefix_hash(t)
        db.apply(OpBatch.inserts([k], [777]))
        assert int(db.lookup([k])[0]) == 777


def test_prefix_hash_stable_across_calls():
    toks = [5, 17, 5, 99]
    assert prefix_hash(toks) == prefix_hash(list(toks))
    assert prefix_hash(toks[:2]) != prefix_hash(toks)  # prefixes differ


@pytest.mark.parametrize("bad", [KEY_MAX, KEY_MAX - 1])
@pytest.mark.parametrize("build", [
    lambda k: OpBatch.inserts([k], [1]),
    lambda k: OpBatch.deletes([3, k]),
    lambda k: OpBatch.searches([k]),
    lambda k: OpBatch.ranges([k], [5]),
    lambda k: OpBatch.ranges([1], [k]),
    lambda k: OpBatch.from_ops([(0, k, 1)]),
])
def test_builders_reject_both_sentinel_keys(build, bad):
    """Front-door guard (satellite of the silent-loss fix): every plan
    builder raises on BOTH sentinels — KEY_MAX (the padding sentinel) and
    KEY_MAX - 1 (the kernels' internal pad) — before any device work."""
    with pytest.raises(ValueError, match="sentinel"):
        build(bad)


def test_updates_and_lookup_keep_keymax_as_mask_encoding():
    """`OpBatch.updates` and `Uruv.lookup` keep KEY_MAX as the DOCUMENTED
    NOP/mask-out encoding (the legacy announce shape); only the
    undocumented KEY_MAX - 1 is rejected."""
    b = OpBatch.updates([5, KEY_MAX], [50, 1])
    assert np.asarray(b.codes).tolist()[1] == 3  # OP_NOP
    with pytest.raises(ValueError):
        OpBatch.updates([KEY_MAX - 1], [1])
    db = Uruv(CFG)
    db.apply(OpBatch.inserts([5], [50]))
    assert db.lookup([5, KEY_MAX]).tolist() == [50, NOT_FOUND]
    with pytest.raises(ValueError):
        db.lookup([KEY_MAX - 1])


# --------------------------------------------------- apply_nowait / confirm
def test_apply_nowait_confirm_matches_sync_apply():
    """Deferred dispatch is invisible: values AND timestamps bit-exact
    with the synchronous path on an identical store."""
    rng = np.random.default_rng(3)
    db_a, db_b = Uruv(CFG), Uruv(CFG)
    for _ in range(8):
        n = int(rng.integers(1, 20))
        keys = rng.integers(1, 500, n).astype(np.int32)
        codes = rng.integers(0, 3, n).astype(np.int32)  # INSERT/DELETE/SEARCH
        plan = OpBatch(codes, keys, (keys % 97 + 1).astype(np.int32))
        pending = db_a.apply_nowait(plan, pad_to_pow2=True)
        ra = db_a.confirm(pending)
        if ra is None:                       # rejected: the documented path
            full = db_a.apply(pending.batch)
            ra = type(full)(
                values=np.asarray(full.values)[:n],
                found=np.asarray(full.found)[:n],
                timestamps=np.asarray(full.timestamps)[:n],
                range_index=full.range_index,
                range_pages=full.range_pages,
                range_resume=full.range_resume)
        rb = db_b.apply(plan, pad_to_pow2=True)
        np.testing.assert_array_equal(np.asarray(ra.values)[:n],
                                      np.asarray(rb.values))
        np.testing.assert_array_equal(np.asarray(ra.timestamps)[:n],
                                      np.asarray(rb.timestamps))
        np.testing.assert_array_equal(np.asarray(ra.found)[:n],
                                      np.asarray(rb.found))
    assert db_a.ts == db_b.ts


def test_apply_nowait_rejects_range_and_empty():
    db = Uruv(CFG)
    with pytest.raises(ValueError, match="RANGE"):
        db.apply_nowait(OpBatch.ranges([1], [5]))
    with pytest.raises(ValueError, match="non-empty"):
        db.apply_nowait(OpBatch.empty())


def test_rejection_rolls_back_and_replays_bit_exact():
    """A capacity-rejected speculative plan leaves no trace: confirm
    returns None, the clock is restored, and replaying the SAME padded
    plan through apply() lands on the same timestamps a never-pipelined
    client would produce."""
    keys = np.arange(1, 33, dtype=np.int32)  # 32 new keys, one leaf region
    db = Uruv(CFG)
    ts0 = db.ts
    pending = db.apply_nowait(OpBatch.inserts(keys, keys * 10),
                              pad_to_pow2=True)
    assert db.confirm(pending) is None          # leaf_cap=8 -> fast-path reject
    assert db.ts == ts0                          # clock rolled back
    res = db.apply(pending.batch)                # slow-path replay
    assert np.asarray(res.timestamps)[0] == ts0
    # mirror client that never speculated
    db2 = Uruv(CFG)
    res2 = db2.apply(OpBatch.inserts(keys, keys * 10), pad_to_pow2=True)
    np.testing.assert_array_equal(np.asarray(res.values)[:32],
                                  np.asarray(res2.values))
    assert db.ts == db2.ts
    np.testing.assert_array_equal(db.lookup(keys), db2.lookup(keys))


def test_depth_two_speculation_sees_prior_plan():
    db = Uruv(CFG)
    p1 = db.apply_nowait(OpBatch.inserts([10, 11], [100, 110]),
                         pad_to_pow2=True)
    p2 = db.apply_nowait(OpBatch.searches([10, 11]), pad_to_pow2=True)
    r1, r2 = db.confirm(p1), db.confirm(p2)
    assert r1 is not None and r2 is not None
    assert np.asarray(r2.values).tolist() == [100, 110]


# ------------------------------------------------------------- coalescer
def _mirror_check(coalescer, futures, cfg, prefill_plan):
    """Replay the coalescer's dispatch log through a fresh synchronous
    client and demand bit-exact per-client results."""
    db2 = Uruv(cfg)
    if prefill_plan is not None:
        db2.apply(prefill_plan)
    resolved = {}
    for plan, spans in coalescer.dispatch_log:
        res = db2.apply(plan)  # plan is exactly as dispatched (padded)
        for fut, a, b in spans:
            resolved[id(fut)] = (
                np.asarray(res.values)[a:b],
                np.asarray(res.found)[a:b],
                np.asarray(res.timestamps)[a:b],
                [(int(p) - a, res.page(int(p)))
                 for p in np.asarray(res.range_index) if a <= int(p) < b],
            )
    assert len(resolved) == len(futures)
    for fut in futures:
        got = fut.result()
        want_v, want_f, want_t, want_pages = resolved[id(fut)]
        np.testing.assert_array_equal(np.asarray(got.values), want_v)
        np.testing.assert_array_equal(np.asarray(got.found), want_f)
        np.testing.assert_array_equal(np.asarray(got.timestamps), want_t)
        got_pages = [(int(p), got.page(int(p)))
                     for p in np.asarray(got.range_index)]
        assert got_pages == want_pages


def test_coalescer_bit_exact_under_skewed_bursty_load():
    """THE property test: zipfian-skewed bursty closed-loop traffic with
    RANGE-mixed requests through the pipelined coalescer produces, per
    client, the bit-exact values / found / TIMESTAMPS / range pages of
    the same coalesced plans applied synchronously — speculation,
    rejection replay (leaf_cap=8 guarantees rejections), sync-path
    RANGE detours, and future slicing are all invisible."""
    cfg = CFG
    rng = np.random.default_rng(17)
    hot = rng.choice(2000, 24, replace=False).astype(np.int32) + 1
    prefill = OpBatch.inserts(hot, hot * 3)
    db = Uruv(cfg)
    db.apply(prefill)
    c = Coalescer(db, AdmissionPolicy(start_width=16, max_width=64,
                                      base_deadline_s=1e-4), record=True)
    futures = []
    for wave in range(12):
        for _ in range(int(rng.integers(2, 10))):   # bursty wave sizes
            n = int(rng.integers(1, 5))
            parts = []
            for _ in range(n):
                r = rng.random()
                # zipfian-ish: 70% of traffic on the 24 hot keys
                k = int(hot[rng.integers(0, 4)]) if r < 0.7 \
                    else int(rng.integers(1, 4000))
                if r < 0.25:
                    parts.append(OpBatch.inserts([k], [k % 89 + 1]))
                elif r < 0.4:
                    parts.append(OpBatch.deletes([k]))
                elif r < 0.9:
                    parts.append(OpBatch.searches([k]))
                else:                                # RANGE mixed into CRUD
                    parts.append(OpBatch.ranges([k], [k + 50]))
            futures.append(c.submit(OpBatch.concat(*parts)))
        c.pump(force=bool(wave % 3 == 0))
        if wave % 4 == 1:
            futures[int(rng.integers(0, len(futures)))].result()
    c.flush()
    assert all(f.done for f in futures)
    assert c.stats["plans"] == len(c.dispatch_log)
    assert c.stats["plans_sync"] > 0              # RANGE detours happened
    _mirror_check(c, futures, cfg, prefill)


def test_coalescer_rejection_replay_bit_exact():
    """Force fast-path rejections WITH a trailing speculative plan in
    flight; the replay path must still be bit-exact vs the mirror."""
    cfg = CFG
    db = Uruv(cfg)
    c = Coalescer(db, AdmissionPolicy(start_width=64, max_width=64),
                  record=True)
    futures = [c.submit(OpBatch.inserts(np.arange(100, 132, dtype=np.int32),
                                        np.int32(7)))]
    c.pump(force=True)                      # dispatch (will reject: 1 leaf)
    futures.append(c.submit(OpBatch.searches(np.arange(100, 104,
                                                       dtype=np.int32))))
    c.pump(force=True)                      # second plan speculates behind it
    c.flush()
    assert c.stats["plans_rejected"] >= 1 and c.stats["replays"] >= 2
    _mirror_check(c, futures, cfg, None)


def test_coalescer_deep_queue_drains_fifo():
    """10k-deep admission queue (the O(n) list.pop(0) regression class):
    submits are O(1), the drain is linear in plans, results stay FIFO."""
    db = Uruv(UruvConfig(leaf_cap=64, max_leaves=1 << 11,
                         max_versions=1 << 15))
    c = Coalescer(db, AdmissionPolicy(start_width=64, max_width=64))
    n = 10_000
    keys = np.random.default_rng(5).choice(200_000, n, replace=False) \
        .astype(np.int32) + 1
    futs = [c.submit(OpBatch.inserts([int(k)], [int(k) % 50 + 1]))
            for k in keys]
    assert isinstance(c.queue, collections.deque)
    assert c.stats["max_queue_depth"] == n
    c.flush()
    ts = np.array([int(np.asarray(f.result().timestamps)[0]) for f in futs])
    assert (np.diff(ts) > 0).all()          # FIFO linearization order
    assert db.lookup(keys[:100]).tolist() == \
        [int(k) % 50 + 1 for k in keys[:100]]


def test_coalescer_exclusive_store_donation_single_depth():
    db = Uruv(CFG)
    c = Coalescer(db, AdmissionPolicy(start_width=8), exclusive=True)
    assert c._depth == 1
    futs = [c.submit(OpBatch.inserts([k], [k * 2])) for k in range(1, 9)]
    c.flush()
    assert [int(np.asarray(f.result().values)[0]) for f in futs] == [-1] * 8
    assert db.lookup(np.arange(1, 9)).tolist() == \
        (np.arange(1, 9) * 2).tolist()


def test_coalescer_adapts_width_on_rejection():
    db = Uruv(CFG)
    c = Coalescer(db, AdmissionPolicy(start_width=64, max_width=64))
    c.submit(OpBatch.inserts(np.arange(1, 33, dtype=np.int32), np.int32(1)))
    c.flush()                               # rejects -> halves target
    assert c.target_width < 64

    # hot (all-duplicate) traffic marks the segment and contracts policy
    c2 = Coalescer(db, AdmissionPolicy(start_width=8))
    for _ in range(8):
        c2.submit(OpBatch.inserts([77], [1]))
    c2.flush()
    assert c2.stats["hot_segments"] >= 1
    assert c2._deadline_s() < c2.policy.base_deadline_s

    of = OpFuture(c2, 1)
    assert not of.done
