"""Boundary-key correctness across both backends (ISSUE 5 satellite).

Sweeps the edges of the key domain — KEY_MIN+1 (one above the left
separator sentinel) and KEY_MAX-1/KEY_MAX-2 (just under the padding
sentinel) — through inserts, deletes, searches and ranges, plus ranges
that straddle the left sentinel and the duplicate-separator-after-merge
scenario, under both the XLA oracle and the Pallas interpreter.
"""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import backend as BE
from repro.core import batch as B
from repro.core import lifecycle as LC
from repro.core import store as S
from repro.core.index import KEY_MIN
from repro.core.ref import (
    KEY_MAX, NOT_FOUND, OP_DELETE, OP_INSERT, OP_SEARCH, RefStore,
)

BACKENDS = ["xla", "pallas_interpret"]

LO = KEY_MIN + 1          # smallest usable key (KEY_MIN is the sentinel)
HI = KEY_MAX - 2          # largest usable key (< KEY_MAX - 1 per ref.py)
EDGES = [LO, LO + 1, -1, 0, 1, HI - 1, HI]


def _cfg():
    return S.UruvConfig(leaf_cap=8, max_leaves=128, max_versions=1 << 12,
                        tracker_cap=16, max_chain=16, index_fanout=4)


@pytest.fixture(autouse=True)
def _reset_backend():
    yield
    BE.set_backend(None)


@pytest.mark.parametrize("backend", BACKENDS)
def test_edge_key_crud(backend):
    BE.set_backend(backend)
    st = S.create(_cfg())
    ref = RefStore()
    keys = np.asarray(EDGES, np.int32)
    vals = np.arange(1, len(keys) + 1, dtype=np.int32)
    ops = [(OP_INSERT, int(k), int(v)) for k, v in zip(keys, vals)]
    st, res = B.apply_batch(st, ops)
    assert res == ref.apply_batch(ops)
    S.check_invariants(st)

    probe = np.asarray(EDGES + [LO - 1 + 2, HI + 1], np.int32)
    got = np.asarray(S.bulk_lookup(st, probe, int(st.ts)))
    want = [ref.search_at(int(k), ref.ts) for k in probe]
    assert got.tolist() == want

    # delete the extremes, re-search
    ops = [(OP_DELETE, LO, 0), (OP_DELETE, HI, 0),
           (OP_SEARCH, LO, 0), (OP_SEARCH, HI, 0)]
    st, res = B.apply_batch(st, ops)
    assert res == ref.apply_batch(ops)
    S.check_invariants(st)


@pytest.mark.parametrize("backend", BACKENDS)
def test_edge_ranges_and_left_sentinel_straddle(backend):
    BE.set_backend(backend)
    st = S.create(_cfg())
    ref = RefStore()
    keys = np.concatenate([
        np.asarray(EDGES, np.int64),
        np.arange(-50, 50, 7, dtype=np.int64),
    ]).astype(np.int32)
    vals = (np.arange(len(keys)) + 1).astype(np.int32)
    ops = [(OP_INSERT, int(k), int(v)) for k, v in zip(keys, vals)]
    st, res = B.apply_batch(st, ops)
    ref.apply_batch(ops)
    ts = int(st.ts)

    intervals = [
        (KEY_MIN, KEY_MAX - 2),      # everything, from the sentinel itself
        (KEY_MIN, 0),                # straddles the left sentinel
        (KEY_MIN + 1, KEY_MIN + 1),  # point query at the smallest key
        (LO, LO),
        (HI, HI),
        (HI - 1, KEY_MAX - 2),       # right edge window
        (0, -1),                     # inverted: empty, never truncated
        (-10, 10),
    ]
    k1 = np.asarray([a for a, _ in intervals], np.int32)
    k2 = np.asarray([b for _, b in intervals], np.int32)
    pages = B.bulk_range_all(st, k1, k2, ts, max_results=16,
                             scan_leaves=2, max_rounds=2)
    for (a, b), got in zip(intervals, pages):
        assert got == ref.range_query(int(a), int(b), ts), (a, b)


@pytest.mark.parametrize("backend", BACKENDS)
def test_duplicate_separator_after_merge(backend):
    """A separator deleted by a leaf merge may be re-created verbatim by
    a later split; descent, ranges and invariants must hold across the
    delete/merge/re-insert cycle."""
    BE.set_backend(backend)
    st = S.create(_cfg())
    ref = RefStore()
    keys = np.arange(0, 32, dtype=np.int32)
    ops = [(OP_INSERT, int(k), int(k) + 1) for k in keys]
    st, _ = B.apply_batch(st, ops)
    ref.apply_batch(ops)
    assert int(st.n_leaves) >= 3
    seps0 = S.directory(st)[0].tolist()

    # tombstone the upper half, then merge its leaves away
    ops = [(OP_DELETE, int(k), 0) for k in keys[12:]]
    st, _ = B.apply_batch(st, ops)
    ref.apply_batch(ops)
    n0 = int(st.n_leaves)
    for p in range(8):
        st, _, merged = LC.maintain(st, 32, phase=p % 2)
        S.check_invariants(st)
    assert int(st.n_leaves) < n0, "no leaf merge happened; resize the test"
    assert S.live_items(st) == ref.live_items()

    # re-insert: splits may re-create previously deleted separators
    ops = [(OP_INSERT, int(k), int(k) + 7) for k in keys[8:]]
    st, res = B.apply_batch(st, ops)
    assert res == ref.apply_batch(ops)
    S.check_invariants(st)
    seps1 = S.directory(st)[0].tolist()
    assert len(set(seps1)) == len(seps1), "duplicate live separators"
    ts = int(st.ts)
    got = B.bulk_range_all(st, [0], [64], ts, max_results=64)[0]
    assert got == ref.range_query(0, 64, ts)
    assert S.live_items(st) == ref.live_items()
