"""Per-kernel interpret-mode sweeps vs pure-jnp oracles (shapes x dtypes)."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.ref import KEY_MAX, NOT_FOUND
from repro.kernels.uruv_search.uruv_search import leaf_slots, search_positions
from repro.kernels.uruv_search.ref import leaf_slots_ref, search_positions_ref
from repro.kernels.uruv_search.ops import locate
from repro.kernels.uruv_range.ops import range_scan
from repro.kernels.versioned_read.versioned_read import versioned_read
from repro.kernels.versioned_read.ref import versioned_read_ref
from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.decode_attention.decode_attention import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref

RNG = np.random.default_rng(42)


@pytest.mark.parametrize("n_dir,n_q,bq,bd", [
    (64, 16, 8, 16), (1000, 333, 64, 128), (4096, 256, 256, 512),
    (7, 5, 8, 8),
])
def test_search_positions_sweep(n_dir, n_q, bq, bd):
    d = np.sort(RNG.choice(10**6, n_dir, replace=False)).astype(np.int32)
    d[0] = -(2**31)
    q = RNG.integers(-10, 10**6 + 10, n_q).astype(np.int32)
    got = search_positions(jnp.asarray(d), jnp.asarray(q),
                           block_q=bq, block_dir=bd)
    want = search_positions_ref(jnp.asarray(d), jnp.asarray(q))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("fanout,n_sep,n_q,bq", [
    (4, 40, 64, 16), (8, 200, 333, 64), (16, 250, 64, 256),
])
def test_index_descend_sweep(fanout, n_sep, n_q, bq):
    """Blocked F-way multi-level descent kernel vs the pure-jnp oracle
    (and the flat searchsorted rank) across fanouts/depths."""
    from repro.core import index as I
    from repro.kernels.uruv_search.uruv_search import index_descend
    from repro.kernels.uruv_search.ref import index_descend_ref

    ML = 256
    seps = np.sort(RNG.choice(10**6, n_sep, replace=False)).astype(np.int32)
    seps[0] = -(2**31)
    pad_k = np.full(ML, KEY_MAX, np.int32)
    pad_k[:n_sep] = seps
    pad_l = np.full(ML, -1, np.int32)
    pad_l[:n_sep] = np.arange(n_sep, dtype=np.int32)
    idx = I.build(I.index_config(ML, fanout), ML, pad_k, pad_l,
                  jnp.asarray(n_sep, jnp.int32))
    q = np.concatenate([
        RNG.integers(-10, 10**6 + 10, n_q).astype(np.int32),
        seps[:8], seps[:8] + 1, np.array([KEY_MAX - 1], np.int32),
    ])
    got = index_descend(idx.node_keys, idx.node_child, jnp.asarray(q),
                        block_q=bq)
    want = index_descend_ref(idx.node_keys, idx.node_child, jnp.asarray(q))
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    # descent rank == flat searchsorted over the live separators
    ordgot = np.asarray(I.leaf_ordinal(idx, got[0], got[1]))
    ordwant = np.maximum(
        np.searchsorted(seps, q, side="right").astype(np.int32) - 1, 0)
    np.testing.assert_array_equal(ordgot, ordwant)


@pytest.mark.parametrize("P,L,bq", [(16, 8, 8), (100, 32, 32), (257, 16, 64)])
def test_leaf_slots_sweep(P, L, bq):
    rows = np.sort(RNG.integers(0, 500, (P, L)), axis=1).astype(np.int32)
    q = RNG.integers(0, 520, P).astype(np.int32)
    s1, e1 = leaf_slots(jnp.asarray(rows), jnp.asarray(q), block_q=bq)
    s2, e2 = leaf_slots_ref(jnp.asarray(rows), jnp.asarray(q))
    np.testing.assert_array_equal(s1, s2)
    np.testing.assert_array_equal(e1, e2)


def test_locate_end_to_end_matches_store():
    from repro.core import store as S
    from repro.core import batch as B

    st = S.create(S.UruvConfig(leaf_cap=8, max_leaves=128, max_versions=4096))
    keys = RNG.choice(1000, 100, replace=False).astype(np.int32)
    for i in range(0, 100, 16):
        st, _ = B.apply_updates(st, keys[i:i+16], keys[i:i+16])
    q = RNG.integers(0, 1100, 64).astype(np.int32)
    bnode, bslot, leaf, slot, exists = locate(
        st.index.node_keys, st.index.node_child, st.leaf_keys,
        jnp.asarray(q), use_pallas=True, interpret=True)
    vals = np.where(np.asarray(exists),
                    np.asarray(q), -1)
    live = dict(S.live_items(st))
    for k, e in zip(q.tolist(), np.asarray(exists).tolist()):
        assert e == (k in live)


@pytest.mark.parametrize("MV,P,chain", [(128, 64, 4), (1024, 200, 16)])
def test_versioned_read_sweep(MV, P, chain):
    ts = RNG.integers(0, 50, MV).astype(np.int32)
    nxt = RNG.integers(-1, MV, MV).astype(np.int32)
    val = RNG.integers(0, 99, MV).astype(np.int32)
    vh = RNG.integers(-1, MV, P).astype(np.int32)
    snap = RNG.integers(0, 50, P).astype(np.int32)
    a = versioned_read(jnp.asarray(vh), jnp.asarray(snap), jnp.asarray(ts),
                       jnp.asarray(nxt), jnp.asarray(val),
                       max_chain=chain, block_q=64)
    b = versioned_read_ref(jnp.asarray(vh), jnp.asarray(snap),
                           jnp.asarray(ts), jnp.asarray(nxt),
                           jnp.asarray(val), max_chain=chain)
    np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("Q,Sw,ML,L,MV,chain,bq", [
    (16, 2, 64, 8, 256, 4, 8),
    (100, 4, 128, 16, 1024, 8, 32),
    (257, 3, 64, 8, 512, 16, 64),
])
def test_range_scan_kernel_sweep(Q, Sw, ML, L, MV, chain, bq):
    """uruv_range pallas (interpret) vs the pure-jnp oracle on random
    pools: same candidate keys AND snapshot-resolved values."""
    lkeys = np.sort(RNG.integers(0, 1000, (ML, L)), axis=1).astype(np.int32)
    lvh = RNG.integers(-1, MV, (ML, L)).astype(np.int32)
    lcnt = RNG.integers(0, L + 1, ML).astype(np.int32)
    vts = RNG.integers(0, 60, MV).astype(np.int32)
    vnxt = RNG.integers(-1, MV, MV).astype(np.int32)
    vval = RNG.integers(-2, 99, MV).astype(np.int32)   # includes NOT_FOUND-ish
    lids = RNG.integers(0, ML, (Q, Sw)).astype(np.int32)
    pvalid = RNG.random((Q, Sw)) < 0.8
    k1 = RNG.integers(0, 1000, Q).astype(np.int32)
    k2 = (k1 + RNG.integers(-50, 400, Q)).astype(np.int32)  # some inverted
    snap = RNG.integers(0, 60, Q).astype(np.int32)
    args = (jnp.asarray(lids), jnp.asarray(pvalid), jnp.asarray(k1),
            jnp.asarray(k2), jnp.asarray(snap), jnp.asarray(lkeys),
            jnp.asarray(lvh), jnp.asarray(lcnt), jnp.asarray(vts),
            jnp.asarray(vnxt), jnp.asarray(vval))
    gk, gv = range_scan(*args, max_chain=chain, block_q=bq, use_pallas=True,
                        interpret=True)
    wk, wv = range_scan(*args, max_chain=chain, use_pallas=False)
    np.testing.assert_array_equal(gk, wk)
    np.testing.assert_array_equal(gv, wv)


def test_bulk_range_backend_parity_end_to_end():
    """store.bulk_range: pallas_interpret backend == xla backend on a real
    store (keys, values, counts, truncation flags, resume points)."""
    from repro.core import store as S
    from repro.core import batch as B

    st = S.create(S.UruvConfig(leaf_cap=8, max_leaves=128, max_versions=4096))
    keys = RNG.choice(500, 120, replace=False).astype(np.int32)
    for i in range(0, 120, 16):
        st, _ = B.apply_updates(st, keys[i:i+16], keys[i:i+16] % 97)
    ts = int(st.ts)
    k1 = RNG.integers(0, 500, 32).astype(np.int32)
    k2 = (k1 + RNG.integers(-20, 200, 32)).astype(np.int32)
    snap = np.full(32, ts, np.int32)
    a = S.bulk_range(st, k1, k2, snap, max_results=32, scan_leaves=2,
                     max_rounds=3, backend="xla")
    b = S.bulk_range(st, k1, k2, snap, max_results=32, scan_leaves=2,
                     max_rounds=3, backend="pallas_interpret")
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("B,H,KVH,S,D,causal,win,dtype", [
    (2, 4, 2, 96, 32, True, 0, np.float32),
    (1, 4, 1, 64, 16, False, 0, np.float32),
    (2, 8, 4, 80, 32, True, 24, np.float32),
    (1, 2, 2, 64, 64, True, 0, np.float32),
    (1, 4, 2, 64, 32, True, 16, "bfloat16"),
])
def test_flash_attention_sweep(B, H, KVH, S, D, causal, win, dtype):
    q = RNG.standard_normal((B, H, S, D)).astype(np.float32)
    k = RNG.standard_normal((B, KVH, S, D)).astype(np.float32)
    v = RNG.standard_normal((B, KVH, S, D)).astype(np.float32)
    if dtype == "bfloat16":
        q, k, v = (jnp.asarray(x, jnp.bfloat16) for x in (q, k, v))
        tol = 2e-2
    else:
        q, k, v = map(jnp.asarray, (q, k, v))
        tol = 2e-5
    a = flash_attention(q, k, v, causal=causal, window=win,
                        block_q=32, block_k=32)
    b = attention_ref(q, k, v, causal=causal, window=win)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("B,H,KVH,S,D,bk", [
    (3, 8, 2, 100, 32, 32), (2, 4, 4, 64, 16, 16), (1, 8, 1, 130, 64, 64),
])
def test_decode_attention_sweep(B, H, KVH, S, D, bk):
    q = jnp.asarray(RNG.standard_normal((B, H, D)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, KVH, S, D)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, KVH, S, D)), jnp.float32)
    lens = jnp.asarray(RNG.integers(1, S + 1, B), jnp.int32)
    a = decode_attention(q, k, v, lens, block_k=bk)
    b = decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(a, b, atol=2e-5, rtol=2e-5)


def test_decode_attention_partial_stats_combine():
    """Sequence-sharded decode: combining per-shard (m, l, acc) equals the
    unsharded result — the long-context distribution path."""
    B, H, KVH, S, D = 2, 4, 2, 64, 16
    q = jnp.asarray(RNG.standard_normal((B, H, D)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, KVH, S, D)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, KVH, S, D)), jnp.float32)
    lens = jnp.full((B,), S, jnp.int32)
    full = decode_attention_ref(q, k, v, lens)
    halves = []
    for sl in (slice(0, S // 2), slice(S // 2, S)):
        o, m, l = decode_attention(
            q, k[:, :, sl], v[:, :, sl],
            jnp.full((B,), sl.stop - sl.start, jnp.int32),
            block_k=16, return_stats=True)
        halves.append((np.asarray(o, np.float64), np.asarray(m, np.float64),
                       np.asarray(l, np.float64)))
    (o1, m1, l1), (o2, m2, l2) = halves
    m = np.maximum(m1, m2)
    l = l1 * np.exp(m1 - m) + l2 * np.exp(m2 - m)
    o = (o1 * (l1 * np.exp(m1 - m)) + o2 * (l2 * np.exp(m2 - m))) / l
    np.testing.assert_allclose(o, np.asarray(full, np.float64),
                               atol=1e-5, rtol=1e-5)
