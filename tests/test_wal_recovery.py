"""The durability battery (DESIGN.md Sec 14).

kill -9 crash points -> fresh-process recovery -> result-level
bit-equality against an uninterrupted oracle:

  * subprocess workers apply a seeded plan stream against a durable
    client and are SIGKILLed at randomized crash points (mid-WAL-append,
    pre/post fsync, between checkpoint tmp-write and rename, between
    rename and GC) via ``repro.distributed.fault.crash_point``;
  * the parent (a fresh process w.r.t. the kill) recovers the directory
    and must land on a prefix of the plan stream that (a) covers every
    acked plan and (b) matches the RefStore/volatile-oracle replay of
    exactly that prefix — values, found masks, AND version timestamps
    (historical lookups at sampled snapshots pin them);
  * the recovered client then finishes the workload and must equal the
    full-run oracle — recovery is a working client, not a read-only view.

Plus the torn-record corpus (truncated tails, bit-flipped CRCs,
duplicate records, duplicated segment files), the recovery property
test across MTASet-style op mixes with growth-boundary crashes, and the
``.tmp_step_*`` leak regression for CheckpointManager.
"""

import json
import os
import shutil
import signal
import subprocess
import sys
import zlib
from pathlib import Path

import numpy as np
import pytest

import _wal_workload as W
from repro.api import LifecyclePolicy, OpBatch, Uruv, UruvConfig
from repro.checkpoint.manager import CheckpointManager
from repro.durability import (
    Durability, Wal, WalCorruptionError, WalReplayError, recover,
)
from repro.durability.wal import REC_HEADER, PAY_HEADER
from repro.durability.recovery import replay

TESTS_DIR = os.path.dirname(os.path.abspath(__file__))


def worker_env(durable_dir, *, seed, n_plans, width, mix, ckpt=0,
               crash=None, maintain=False, maintain_every=0):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [env.get("PYTHONPATH", ""), TESTS_DIR])
    env.update({
        "URUV_W_DIR": str(durable_dir), "URUV_W_SEED": str(seed),
        "URUV_W_PLANS": str(n_plans), "URUV_W_WIDTH": str(width),
        "URUV_W_MIX": mix, "URUV_W_CKPT": str(ckpt),
        "URUV_W_MAINTAIN": "1" if maintain else "0",
        "URUV_W_MAINTAIN_EVERY": str(maintain_every),
    })
    env.pop("URUV_CRASH_POINT", None)
    if crash is not None:
        env["URUV_CRASH_POINT"] = crash
    return env


def run_worker(env, *, expect_kill):
    p = subprocess.run(
        [sys.executable, "-c",
         "import _wal_workload; _wal_workload.worker_main()"],
        env=env, capture_output=True, text=True, timeout=600,
    )
    if expect_kill:
        assert p.returncode == -signal.SIGKILL, \
            f"worker survived its crash point: rc={p.returncode}\n{p.stderr}"
    else:
        assert p.returncode == 0, p.stderr
    return p


# ---------------------------------------------------------------------------
# the kill -9 battery
# ---------------------------------------------------------------------------

# (crash selector, checkpoint cadence) — the :k suffix randomizes WHEN the
# kill lands (k-th hit) without randomizing the code path; cadence 4 with
# 12 plans makes the ckpt.* points hit the FULL save (first hit) and the
# DELTA save (:2 — the chain publish is its own crash surface)
BATTERY = [
    ("wal.mid_append:2", 0),
    ("wal.mid_append:7", 4),
    ("wal.pre_fsync:9", 4),
    ("wal.post_fsync:3", 0),
    ("wal.post_fsync:10", 4),
    ("ckpt.tmp_written", 4),
    ("ckpt.tmp_written:2", 4),
    ("ckpt.renamed", 4),
    ("ckpt.renamed:2", 4),
]


@pytest.mark.slow
@pytest.mark.parametrize("crash,ckpt", BATTERY,
                         ids=[c for c, _ in BATTERY])
def test_kill9_battery(tmp_path, crash, ckpt):
    seed, n_plans, width, mix = 7, 12, 16, "update"
    env = worker_env(tmp_path, seed=seed, n_plans=n_plans, width=width,
                     mix=mix, ckpt=ckpt, crash=crash)
    run_worker(env, expect_kill=True)

    plans = W.make_plans(seed, n_plans, width, mix)
    acked = W.read_acked(tmp_path)
    db = Uruv.recover(tmp_path, policy=W.policy(False))
    assert db.ts % width == 0
    m = db.ts // width
    # the durability invariant: everything acked survived the kill
    assert acked <= m <= n_plans, (acked, m)
    assert db.recovery.replayed_plans + (0 if db.recovery.checkpoint_step
                                         is None else
                                         db.recovery.checkpoint_step
                                         // width) == m
    assert W.summarize(db) == W.ref_summary(plans, m)
    db.durability.close()

    # a recovered directory is a working store: finish the workload in a
    # second (resuming) worker process, recover again, compare full run
    run_worker(worker_env(tmp_path, seed=seed, n_plans=n_plans, width=width,
                          mix=mix, ckpt=ckpt), expect_kill=False)
    db2 = Uruv.recover(tmp_path, policy=W.policy(False))
    assert db2.ts == n_plans * width
    assert W.summarize(db2) == W.ref_summary(plans, n_plans)
    db2.durability.close()


def test_mid_append_tear_is_truncated_byte_exactly(tmp_path):
    """Dying mid-append leaves exactly half a record; open() must report
    precisely those bytes and the next open must be clean."""
    seed, n_plans, width, mix = 11, 8, 16, "update"
    env = worker_env(tmp_path, seed=seed, n_plans=n_plans, width=width,
                     mix=mix, crash="wal.mid_append:5")
    run_worker(env, expect_kill=True)

    db = Uruv.recover(tmp_path, policy=W.policy(False))
    rep = db.recovery.wal
    rec_bytes = REC_HEADER.size + PAY_HEADER.size + 12 * width
    assert rep.torn_tail
    assert rep.truncated_bytes == rec_bytes // 2
    assert rep.truncated_segment == "wal_00000001.log"
    assert db.ts // width == 4          # plans 1-4 survived, 5 was torn
    db.durability.close()

    db2 = Uruv.recover(tmp_path, policy=W.policy(False))
    assert not db2.recovery.wal.torn_tail
    assert db2.recovery.wal.truncated_bytes == 0
    db2.durability.close()


def test_group_commit_crash_loses_at_most_window(tmp_path):
    """group_commit=k: an un-fsynced window may die, but never an fsynced
    plan — and a flushed coalescer (confirm-after-fsync) never loses."""
    cfg = W.small_config()
    db = Uruv(cfg, durable_dir=tmp_path, group_commit=4)
    db.insert([1, 2, 3], [10, 20, 30])       # plan 1: window pending
    db.insert([4], [40])                      # plan 2: still pending
    assert db.durability.wal.pending == 2
    db.sync_durable()                         # the coalescer-flush fsync
    db.insert([5], [50])                      # pending again, "crash" here
    assert db.durability.wal.pending == 1
    # simulate the kill: drop the client without close() — the pending
    # record was appended but never fsynced (it MAY survive the page
    # cache; the contract only promises synced plans)
    del db
    db2 = Uruv.recover(tmp_path, group_commit=4)
    assert db2.ts >= 4                        # everything fsynced survived
    assert db2.lookup([1, 2, 3, 4], db2.ts).tolist() == [10, 20, 30, 40]
    db2.durability.close()


# ---------------------------------------------------------------------------
# recovery property test: op mixes x growth/maintain boundary crashes
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("mix", sorted(W.MIXES))
@pytest.mark.parametrize("seed", [1, 2])
def test_recovery_property(tmp_path, mix, seed):
    """Seeded CRUD+range interleavings, killed mid-WAL-append at a
    seed-randomized plan, recovered and compared against the RefStore
    replay of the recovered prefix — values AND version timestamps
    (historical probes).  The workload is sized to cross grow()
    boundaries (asserted on the oracle client)."""
    n_plans, width = 18, 12
    k = 5 + (seed * 7 + len(mix)) % 9        # randomized crash plan
    env = worker_env(tmp_path, seed=seed, n_plans=n_plans, width=width,
                     mix=mix, ckpt=6, crash=f"wal.mid_append:{k}")
    run_worker(env, expect_kill=True)

    plans = W.make_plans(seed, n_plans, width, mix)
    acked = W.read_acked(tmp_path)
    db = Uruv.recover(tmp_path, policy=W.policy(False))
    m = db.ts // width
    assert acked <= m <= n_plans
    assert W.summarize(db) == W.ref_summary(plans, m)

    # the write-heavy plan stream must cross a growth boundary on a
    # volatile oracle (the version pool overflows and auto-grows); the
    # read/range mixes write too few versions to pressure the pools
    oracle = Uruv(W.small_config(), policy=W.policy(False))
    for p in plans:
        oracle.apply(p)
    if mix == "update":
        assert oracle.stats["grows"] >= 1
    db.durability.close()


def test_recovery_across_maintain_boundary(tmp_path):
    """Crashes interleaved with explicit maintain() passes: maintenance
    is never WAL-logged (it changes no result), so recovery replays onto
    a differently-maintained pool — results must still match the oracle
    at the current clock, and a snapshot registered post-recovery must be
    byte-stable under further maintenance."""
    seed, n_plans, width, mix = 3, 16, 12, "update"
    env = worker_env(tmp_path, seed=seed, n_plans=n_plans, width=width,
                     mix=mix, ckpt=5, crash="wal.post_fsync:11",
                     maintain=True, maintain_every=3)
    run_worker(env, expect_kill=True)

    plans = W.make_plans(seed, n_plans, width, mix)
    db = Uruv.recover(tmp_path, policy=W.policy(True))
    m = db.ts // width
    assert W.read_acked(tmp_path) <= m <= n_plans
    # result-level equality at the current clock (maintenance may have
    # reclaimed versions below the snapshot floor, so no historical probe)
    assert W.summarize(db, historical=False) == \
        W.ref_summary(plans, m, historical=False)

    # registered-snapshot byte-stability across post-recovery maintenance
    with db.snapshot() as ts:
        before = db.range(0, W.KEYSPACE, ts)
        db.maintain()
        db.compact()
        assert db.range(0, W.KEYSPACE, ts) == before
    db.durability.close()


# ---------------------------------------------------------------------------
# torn-record corpus (Wal-level, no subprocesses)
# ---------------------------------------------------------------------------

def _write_wal(directory, n_records=6, width=4, base=0):
    wal = Wal.open(directory)
    for i in range(n_records):
        wal.append(base + i * width, np.zeros(width, np.int32),
                   np.arange(width, dtype=np.int32) + i,
                   np.full(width, i + 1, np.int32))
        wal.commit()
    wal.close()
    return sorted(Path(directory).glob("wal_*.log"))


@pytest.mark.parametrize("cut", [1, 44, 72, 100])
def test_torn_tail_truncated_and_reported(tmp_path, cut):
    [seg] = _write_wal(tmp_path / "wal")
    size = seg.stat().st_size
    with open(seg, "r+b") as f:
        f.truncate(size - cut)
    wal = Wal.open(tmp_path / "wal")
    rec_bytes = REC_HEADER.size + PAY_HEADER.size + 12 * 4
    hdr = 16                                        # segment header bytes
    survive = (size - cut - hdr) // rec_bytes       # whole records left
    assert wal.report.n_records == survive
    assert wal.report.torn_tail == ((size - cut - hdr) % rec_bytes != 0)
    assert wal.report.truncated_bytes == (size - cut - hdr) % rec_bytes
    # after truncation the file is clean: reopen reports zero truncated
    wal.close()
    wal2 = Wal.open(tmp_path / "wal")
    assert not wal2.report.torn_tail
    assert wal2.report.n_records == survive
    wal2.close()


def test_bitflip_in_final_segment_truncates_from_there(tmp_path):
    [seg] = _write_wal(tmp_path / "wal")
    data = bytearray(seg.read_bytes())
    rec_bytes = REC_HEADER.size + PAY_HEADER.size + 12 * 4
    flip_at = 16 + 2 * rec_bytes + REC_HEADER.size + 3   # record 3 payload
    data[flip_at] ^= 0x40
    seg.write_bytes(bytes(data))
    wal = Wal.open(tmp_path / "wal")
    assert wal.report.n_records == 2                     # records 1-2 only
    assert wal.report.torn_tail
    assert wal.report.truncated_bytes == 4 * rec_bytes
    wal.close()


def test_bitflip_in_nonfinal_segment_is_rejected(tmp_path):
    # tiny segment_bytes forces rotation -> multiple segments
    wal = Wal.open(tmp_path / "wal", segment_bytes=128)
    for i in range(8):
        wal.append(i * 4, np.zeros(4, np.int32),
                   np.arange(4, dtype=np.int32), np.full(4, i, np.int32))
        wal.commit()
    wal.close()
    segs = sorted(Path(tmp_path / "wal").glob("wal_*.log"))
    assert len(segs) >= 2
    data = bytearray(segs[0].read_bytes())
    data[-5] ^= 0x01                                     # corrupt EARLIER seg
    segs[0].write_bytes(bytes(data))
    with pytest.raises(WalCorruptionError):
        Wal.open(tmp_path / "wal")


def test_duplicate_records_skip_on_replay(tmp_path):
    """A duplicate plan record (same base_ts appended twice — a re-logged
    segment copy) parses fine and is skipped deterministically by the
    next_ts <= clock rule; a GAP is rejected, never silently absorbed."""
    wal = Wal.open(tmp_path / "wal")
    wal.append(0, np.full(2, 0, np.int32), np.array([1, 2], np.int32),
               np.array([10, 20], np.int32))
    wal.append(0, np.full(2, 0, np.int32), np.array([1, 2], np.int32),
               np.array([10, 20], np.int32))              # duplicate
    wal.append(2, np.full(2, 0, np.int32), np.array([3, 4], np.int32),
               np.array([30, 40], np.int32))
    wal.commit()
    db = Uruv(W.small_config())
    assert replay(db, wal.records()) == 2                 # dup skipped
    assert db.ts == 4
    assert db.lookup([1, 2, 3, 4], db.ts).tolist() == [10, 20, 30, 40]

    wal.append(99, np.full(2, 0, np.int32), np.array([5, 6], np.int32),
               np.array([50, 60], np.int32))              # gap: base 99 != 4
    with pytest.raises(WalReplayError):
        replay(db, wal.records())
    wal.close()


def test_duplicated_segment_file_is_rejected(tmp_path):
    """Copying a segment over another seq (an operator replaying backups)
    makes the header's embedded seq disagree with the filename: open()
    refuses it as corruption rather than replaying history twice."""
    wal = Wal.open(tmp_path / "wal", segment_bytes=128)
    for i in range(8):
        wal.append(i * 4, np.zeros(4, np.int32),
                   np.arange(4, dtype=np.int32), np.full(4, i, np.int32))
        wal.commit()
    wal.close()
    segs = sorted(Path(tmp_path / "wal").glob("wal_*.log"))
    assert len(segs) >= 3
    shutil.copy(segs[0], segs[1])            # seq 1 contents under seq 2 name
    with pytest.raises(WalCorruptionError):
        Wal.open(tmp_path / "wal")


def test_headerless_final_segment_is_unlinked(tmp_path):
    [seg] = _write_wal(tmp_path / "wal")
    nxt = seg.parent / "wal_00000002.log"
    nxt.write_bytes(b"URUV")                 # died inside _open_segment
    wal = Wal.open(tmp_path / "wal")
    assert not nxt.exists()
    assert wal.report.n_records == 6
    wal.append(24, np.zeros(4, np.int32), np.zeros(4, np.int32),
               np.zeros(4, np.int32))        # writer still appends cleanly
    wal.commit()
    wal.close()
    assert Wal.open(tmp_path / "wal").report.n_records == 7


# ---------------------------------------------------------------------------
# checkpoint tmp-leak regression + delta-chain integrity
# ---------------------------------------------------------------------------

def test_tmp_step_leak_cleaned_on_open(tmp_path):
    """REGRESSION: _load_existing never removed .tmp_step_* left by a
    crashed async writer — pre-seed a torn tmp dir and require it gone."""
    torn = tmp_path / ".tmp_step_00000005"
    torn.mkdir()
    (torn / "ts.npy").write_bytes(b"half a leaf")
    mgr = CheckpointManager(tmp_path, async_write=False)
    assert not torn.exists()
    assert mgr.latest_step() is None         # junk never became a step

    db = Uruv(W.small_config())
    db.insert([1], [10])
    mgr.save_store(db.store, 1)
    assert mgr.latest_step() == 1            # normal saves still publish


def test_delta_chain_survives_missing_base_rejection(tmp_path):
    """A delta whose base chain is broken must not register as complete."""
    db = Uruv(W.small_config())
    db.insert([1, 2], [10, 20])
    mgr = CheckpointManager(tmp_path, keep=5, async_write=False)
    mgr.save_store(db.store, 2)
    db.insert([3], [30])
    mgr.save_store_delta(db.store, 3)
    shutil.rmtree(tmp_path / "step_00000002")     # break the chain
    mgr2 = CheckpointManager(tmp_path, keep=5, async_write=False)
    assert mgr2.latest_step() is None


def test_delta_gc_keeps_chain_bases(tmp_path):
    db = Uruv(W.small_config())
    db.insert([1], [10])
    mgr = CheckpointManager(tmp_path, keep=1, async_write=False)
    mgr.save_store(db.store, 1)
    for s in (2, 3):
        db.insert([s * 10], [s])
        mgr.save_store_delta(db.store, s)
    # keep=1 keeps only step 3 — but 3 is a delta chained to 2 chained to
    # 1: every base must survive GC
    assert sorted(int(p.name.split("_")[1])
                  for p in tmp_path.glob("step_*")) == [1, 2, 3]
    store, step = mgr.restore_store()
    assert step == 3
    assert Uruv.from_store(store).live_items() == db.live_items()
