"""Sharding rules + sharded Uruv + roofline parser unit tests.

Multi-device behaviour is exercised in subprocesses (jax pins the device
count at first init; the main test process stays single-device)."""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro.distributed.sharding import ShardingPolicy, param_spec
from repro.launch.roofline import model_flops, model_params, parse_hlo


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
POL = ShardingPolicy(fsdp=True)


@pytest.mark.parametrize("path,shape,want", [
    # TP on vocab/heads/ffn dims; FSDP ('data') on a remaining large dim
    ("tok/embed", (128256, 2048), ("model", "data")),
    ("layers/attn/wq", (16, 2048, 32, 64), (None, "data", "model", None)),
    # kv heads (8) don't divide the 16-way model axis -> model falls to D
    ("layers/attn/wk", (16, 2048, 8, 64), (None, "model", None, "data")),
    ("layers/mlp/w1", (16, 2048, 8192), (None, "data", "model")),
    ("layers/mlp/w2", (16, 8192, 2048), (None, "model", "data")),
    # EP: experts over model
    ("layers/moe/w1", (16, 64, 2048, 1024), (None, "model", None, "data")),
    ("layers/ln1/scale", (16, 2048), (None, None)),
])
def test_param_spec_rules(path, shape, want):
    spec = param_spec(path, shape, MESH, POL)
    got = tuple(spec)
    # normalize trailing Nones
    got = got + (None,) * (len(shape) - len(got))
    want = want + (None,) * (len(shape) - len(want))
    assert got[: len(want)] == want, (path, got, want)


def test_param_spec_divisibility_guard():
    # 8 kv heads on a 16-way model axis: falls back to the D dim
    spec = param_spec("layers/attn/wk", (2048, 8, 64), MESH, POL)
    assert tuple(spec) == ("model", None, "data")
    # nothing divisible -> fully replicated
    spec = param_spec("layers/attn/wk", (15, 7, 9), MESH, POL)
    assert all(s is None for s in tuple(spec) + (None,))


def test_model_params_and_flops_sane():
    from repro.config import SHAPES, get_arch

    cfg = get_arch("llama3_2_1b")
    N, N_act = model_params(cfg)
    assert 0.9e9 < N < 1.3e9           # ~0.97B non-embedding
    assert N == N_act                  # dense
    moe = get_arch("olmoe_1b_7b")
    Nm, Nm_act = model_params(moe)
    assert Nm_act < Nm / 3             # 64 experts, top-8

    f_train = model_flops(cfg, SHAPES["train_4k"])
    f_dec = model_flops(cfg, SHAPES["decode_32k"])
    assert f_train > 6 * N * 4096 * 256
    assert f_dec < f_train / 1000


def test_parse_hlo_loop_multiplier():
    hlo = textwrap.dedent("""\
    HloModule test

    %body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
      %p = (s32[], f32[8,8]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[8,8] get-tuple-element(%p), index=1
      %dot.1 = f32[8,8] dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %one = s32[] constant(1)
      %ni = s32[] add(%i, %one)
      ROOT %t = (s32[], f32[8,8]) tuple(%ni, %dot.1)
    }

    %cond (p2: (s32[], f32[8,8])) -> pred[] {
      %p2 = (s32[], f32[8,8]) parameter(0)
      %i2 = s32[] get-tuple-element(%p2), index=0
      %n = s32[] constant(12)
      ROOT %lt = pred[] compare(%i2, %n), direction=LT
    }

    ENTRY %main (a: f32[8,8]) -> f32[8,8] {
      %a = f32[8,8] parameter(0)
      %zero = s32[] constant(0)
      %init = (s32[], f32[8,8]) tuple(%zero, %a)
      %w = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body
      ROOT %out = f32[8,8] get-tuple-element(%w), index=1
    }
    """)
    out = parse_hlo(hlo)
    # dot is 2*8*8*8 = 1024 flops, x12 loop trips
    assert out["flops"] == pytest.approx(1024 * 12)


SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "src")
import numpy as np, jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core import store as S, sharded as SH
from repro.core.ref import RefStore, OP_INSERT

mesh = make_mesh((4,), ("data",))
cfg = SH.ShardedConfig(
    base=S.UruvConfig(leaf_cap=8, max_leaves=128, max_versions=2048),
    key_lo=0, key_hi=400)
st = SH.create(cfg, mesh)
upd, lkp, rq = SH.make_ops(cfg, mesh)
ref = RefStore()
rng = np.random.default_rng(2)
for it in range(8):
    keys = rng.integers(0, 400, 16).astype(np.int32)
    vals = rng.integers(0, 1000, 16).astype(np.int32)
    st, prev, ok = upd(st, jnp.asarray(keys), jnp.asarray(vals))
    assert bool(ok)
    rprev = ref.apply_batch(
        [(OP_INSERT, int(k), int(v)) for k, v in zip(keys, vals)])
    np.testing.assert_array_equal(np.asarray(prev), rprev)
got = lkp(st, jnp.asarray(np.arange(0, 400, 7, dtype=np.int32)),
          jnp.asarray(SH.global_ts(st), jnp.int32))
want = [ref.search_at(int(k), ref.ts) for k in np.arange(0, 400, 7)]
np.testing.assert_array_equal(np.asarray(got), want)
k, v, c, t = rq(st, 50, 350, SH.global_ts(st))
assert SH.merge_range_results(k, v, c) == ref.range_query(50, 350, ref.ts)
assert np.unique(np.asarray(st.ts)).size == 1   # replicated clock agrees
print("SHARDED_OK")
"""


def test_sharded_store_on_4_devices():
    r = subprocess.run(
        [sys.executable, "-c", SHARDED_SCRIPT],
        capture_output=True, text=True, timeout=900,
        cwd=str(Path(__file__).resolve().parents[1]),
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "SHARDED_OK" in r.stdout


SHARDED_RANGE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "src")
import numpy as np, jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core import store as S, sharded as SH, batch as B
from repro.core.ref import RefStore, OP_INSERT, OP_DELETE

mesh = make_mesh((4,), ("data",))
base = S.UruvConfig(leaf_cap=8, max_leaves=128, max_versions=4096)
cfg = SH.ShardedConfig(base=base, key_lo=0, key_hi=400)
st = SH.create(cfg, mesh)
apply_fn = SH.make_apply(cfg, mesh)
range_fn = SH.make_range_apply(cfg, mesh, max_results=64, scan_leaves=4,
                               max_rounds=8)
single = S.create(base)
ref = RefStore()
rng = np.random.default_rng(11)
snaps = []
for it in range(6):
    G = 16
    codes = rng.choice([OP_INSERT, OP_INSERT, OP_INSERT, OP_DELETE], G).astype(np.int32)
    keys = rng.integers(0, 400, G).astype(np.int32)
    vals = rng.integers(0, 1000, G).astype(np.int32)
    st, res = SH.sharded_apply_batch(st, codes, keys, vals, apply_fn=apply_fn)
    ops = [(int(c), int(k), int(v)) for c, k, v in zip(codes, keys, vals)]
    single, sres = B.apply_batch(single, ops)
    ref.apply_batch(ops)
    snaps.append(SH.global_ts(st))
assert SH.global_ts(st) == int(single.ts) == ref.ts

# Q=24 mixed-width intervals (incl. inverted + cross-shard spans), each at
# its OWN historical snapshot: sharded fan-out/gather must be bit-exact
# with single-device bulk_range, version-timestamp resolution included.
Q = 24
k1 = rng.integers(0, 400, Q).astype(np.int32)
k2 = (k1 + rng.integers(-30, 300, Q)).astype(np.int32)
snap = np.array([snaps[i % len(snaps)] for i in range(Q)], np.int32)
got = range_fn(st, jnp.asarray(k1), jnp.asarray(k2), jnp.asarray(snap))
want = S.bulk_range(single, k1, k2, snap, max_results=64,
                    scan_leaves=4, max_rounds=8)
for name, g, w in zip(("keys", "vals", "count", "trunc", "resume"), got, want):
    g, w = np.asarray(g), np.asarray(w)
    if name == "resume":
        # resume only contracts for truncated queries (the complete-query
        # sentinel is k2 on both sides, but shard windows may legally
        # close earlier)
        t = np.asarray(want[3])
        np.testing.assert_array_equal(g[t], w[t])
        continue
    np.testing.assert_array_equal(g, w, err_msg=name)

# and against the oracle at every snapshot
for q in range(Q):
    want_q = (ref.range_query(int(k1[q]), int(k2[q]), int(snap[q]))
              if k1[q] <= k2[q] else [])
    c = int(np.asarray(got[2])[q])
    pairs = list(zip(np.asarray(got[0])[q, :c].tolist(),
                     np.asarray(got[1])[q, :c].tolist()))
    if not bool(np.asarray(got[3])[q]):
        assert pairs == want_q, q
    else:
        assert pairs == want_q[:c], q
print("SHARDED_RANGE_OK")
"""


def test_sharded_range_apply_matches_single_device():
    r = subprocess.run(
        [sys.executable, "-c", SHARDED_RANGE_SCRIPT],
        capture_output=True, text=True, timeout=900,
        cwd=str(Path(__file__).resolve().parents[1]),
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "SHARDED_RANGE_OK" in r.stdout


DIST_TRAIN_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "src")
import numpy as np, jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro.config import get_arch
from repro.data.pipeline import make_batch
from repro.distributed import sharding as shd
from repro.distributed.ctx import use_mesh
from repro.optim import adamw
from repro.train import steps

cfg = get_arch("llama3_2_1b").reduced()
mesh = make_mesh((2, 2), ("data", "model"))
policy = shd.ShardingPolicy(fsdp=True)
state = steps.init_state(cfg, jax.random.key(0))
pshard = shd.param_shardings(state.params, mesh, policy)
scalar = jax.NamedSharding(mesh, jax.sharding.PartitionSpec())
sshard = steps.TrainState(params=pshard,
                          opt=adamw.OptState(m=pshard, v=pshard, step=scalar),
                          step=scalar)
state = jax.tree.map(jax.device_put, state, sshard)
batch = make_batch(cfg, 4, 16, 0)
bshard = shd.named(shd.batch_specs(batch, mesh), mesh)
batch = jax.tree.map(jax.device_put, batch, bshard)
with use_mesh(mesh):
    step = jax.jit(steps.make_train_step(cfg, adamw.AdamWConfig()))
    l0 = None
    for i in range(3):
        state, metrics = step(state, batch)
        if l0 is None:
            l0 = float(metrics["loss"])
assert np.isfinite(float(metrics["loss"]))
# compare against single-logical-device result
state2 = steps.init_state(cfg, jax.random.key(0))
s2, m2 = jax.jit(steps.make_train_step(cfg, adamw.AdamWConfig()))(
    state2, make_batch(cfg, 4, 16, 0))
np.testing.assert_allclose(l0, float(m2["loss"]), rtol=1e-3)
print("DIST_TRAIN_OK")
"""


def test_distributed_train_step_matches_single_device():
    r = subprocess.run(
        [sys.executable, "-c", DIST_TRAIN_SCRIPT],
        capture_output=True, text=True, timeout=900,
        cwd=str(Path(__file__).resolve().parents[1]),
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "DIST_TRAIN_OK" in r.stdout
