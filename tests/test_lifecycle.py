"""Lifecycle battery: self-sizing growth + incremental maintenance.

Covers DESIGN.md Sec 10:
  * grow() is bit-exact (ids, timestamps, directory, tracker preserved);
  * maintain() reclaims frozen split-leavings, merges underfull
    neighbours, and keeps every registered snapshot byte-stable;
  * the capacity-pressure property test: sustained random CRUD through
    ``repro.api`` to >8x the initial leaf pool with ZERO CapacityError,
    oracle (RefStore) equivalence throughout, and the frozen-leaf
    accounting invariant (allocated == live + frozen-dead) at every step;
  * CapacityError diagnostics when growth is disabled;
  * checkpoint round-trips across capacity changes;
  * sharded (4 fake devices) lifecycle == local, bit-identical including
    version timestamps (subprocess; jax pins the device count at init).
"""

import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import lifecycle as LC
from repro.core import store as S
from repro.core.ref import OP_DELETE, OP_INSERT, OP_SEARCH, RefStore
from repro import api


def _small_cfg(**kw):
    base = dict(leaf_cap=8, max_leaves=64, max_versions=1 << 11,
                tracker_cap=16, max_chain=16)
    base.update(kw)
    return api.UruvConfig(**base)


def _assert_accounting(store):
    """Every allocated slot is live (directory-referenced) or frozen-dead:
    ``n_alloc - reclaimed == live + frozen`` at all times."""
    acc = LC.leaf_accounting(store)
    assert acc["n_alloc"] == acc["live"] + acc["dead"], acc
    # frozen flags and index references must be disjoint
    s = jax.device_get(store)
    frozen = np.atleast_2d(np.asarray(s.leaf_frozen))
    refd = np.atleast_2d(np.asarray(s.index.leaf_ent)) >= 0
    for sh in range(frozen.shape[0]):
        assert not frozen[sh][refd[sh]].any(), "index points at frozen leaf"


def _ingest(db, ref, rng, n_rounds, width=96, p_ins=0.6, universe=200_000):
    for _ in range(n_rounds):
        r = rng.random(width)
        codes = np.where(r < p_ins, OP_INSERT,
                         np.where(r < p_ins + 0.2, OP_DELETE,
                                  OP_SEARCH)).astype(np.int32)
        keys = rng.integers(0, universe, width).astype(np.int32)
        vals = (keys % 1000 + 1).astype(np.int32)
        res = db.apply(api.OpBatch(codes, keys, vals))
        if ref is not None:
            want = ref.apply_batch(
                [(int(c), int(k), int(v))
                 for c, k, v in zip(codes, keys, vals)])
            np.testing.assert_array_equal(np.asarray(res.values), want)


# ---------------------------------------------------------------------------
# grow
# ---------------------------------------------------------------------------

def test_grow_is_bit_exact():
    db = api.Uruv(_small_cfg(),
                  policy=api.LifecyclePolicy(auto_grow=False,
                                             auto_maintain=False))
    ref = RefStore()
    _ingest(db, ref, np.random.default_rng(0), 4, width=48, universe=2000)
    st = db.store
    snap = int(st.ts) - 10
    probe = jnp.arange(0, 2000, 7, dtype=jnp.int32)
    before = np.asarray(S.bulk_lookup(st, probe, snap))

    g = LC.grow(st, leaves=True, versions=True, tracker=True)
    assert g.cfg.max_leaves == 2 * st.cfg.max_leaves
    assert g.cfg.max_versions == 2 * st.cfg.max_versions
    assert g.cfg.tracker_cap == 2 * st.cfg.tracker_cap
    ml = st.cfg.max_leaves
    for name in ("leaf_keys", "leaf_vhead", "leaf_count", "leaf_next",
                 "leaf_newnext", "leaf_frozen", "leaf_ts"):
        np.testing.assert_array_equal(
            np.asarray(getattr(g, name))[:ml], np.asarray(getattr(st, name)),
            err_msg=name)
    # the index grows by tail-extension too: every pre-growth node pool is
    # a bit-exact prefix, and the spine/reverse-map entries are preserved
    for l in range(st.index.cfg.depth):
        for fld in ("node_keys", "node_child", "node_cnt"):
            old = np.asarray(getattr(st.index, fld)[l])
            new = np.asarray(getattr(g.index, fld)[l])
            np.testing.assert_array_equal(new[: old.shape[0]], old,
                                          err_msg=f"{fld}[{l}]")
    np.testing.assert_array_equal(
        np.asarray(g.index.leaf_ent)[:ml], np.asarray(st.index.leaf_ent))
    c0 = st.index.cfg.caps[0]
    for fld in ("ord_node", "node_pos", "ord_start"):
        np.testing.assert_array_equal(
            np.asarray(getattr(g.index, fld))[:c0],
            np.asarray(getattr(st.index, fld)), err_msg=fld)
    for name in ("ver_value", "ver_ts", "ver_next"):
        np.testing.assert_array_equal(
            np.asarray(getattr(g, name))[: st.cfg.max_versions],
            np.asarray(getattr(st, name)), err_msg=name)
    for name in ("n_alloc", "n_leaves", "n_vers", "ts", "trk_cursor"):
        assert int(getattr(g, name)) == int(getattr(st, name)), name
    S.check_invariants(g)
    assert S.live_items(g) == ref.live_items()
    # historic snapshot reads are unchanged through the grown store
    np.testing.assert_array_equal(
        np.asarray(S.bulk_lookup(g, probe, snap)), before)
    # pow2 bucketing: growing a non-pow2 pool lands on the next bucket
    assert LC.next_pool_size(48) == 64 and LC.next_pool_size(64) == 128


# ---------------------------------------------------------------------------
# maintain
# ---------------------------------------------------------------------------

def test_maintain_reclaims_frozen_and_merges():
    # pre-sized pool: no capacity pressure, so frozen split-leavings
    # accumulate untouched until the explicit maintain calls below
    pol = api.LifecyclePolicy(auto_maintain=False)
    db = api.Uruv(_small_cfg(max_leaves=1024, max_versions=1 << 13),
                  policy=pol)
    ref = RefStore()
    rng = np.random.default_rng(1)
    # dense ingest -> many splits -> frozen leavings
    keys = rng.choice(4000, 1500, replace=False).astype(np.int32)
    for i in range(0, len(keys), 96):
        db.apply(api.OpBatch.inserts(keys[i:i + 96], keys[i:i + 96] % 97 + 1))
        ref.apply_batch([(OP_INSERT, int(k), int(k) % 97 + 1)
                         for k in keys[i:i + 96]])
    acc0 = LC.leaf_accounting(db.store)
    assert acc0["dead"] > 0, "ingest should leave frozen split-leavings"
    # delete 80% of a contiguous region -> underfull leaves after purge
    dels = np.sort(keys[keys < 3200])
    dels = dels[rng.random(len(dels)) < 0.8].astype(np.int32)
    for i in range(0, len(dels), 96):
        db.apply(api.OpBatch.deletes(dels[i:i + 96]))
        ref.apply_batch([(OP_DELETE, int(k), 0) for k in dels[i:i + 96]])

    n_leaves0 = int(np.asarray(db.store.n_leaves))
    total_rec = total_mer = 0
    for p in range(12):
        rec, mer = db.maintain(48, phase=p)
        total_rec += rec
        total_mer += mer
        S.check_invariants(db.store)
        _assert_accounting(db.store)
    assert total_rec >= acc0["dead"], "frozen leavings were not reclaimed"
    assert total_mer > 0, "underfull neighbours were not merged"
    assert int(np.asarray(db.store.n_leaves)) < n_leaves0
    assert db.live_items() == ref.live_items()
    assert db.stats["maintain_passes"] == 12
    assert db.stats["leaves_reclaimed"] == total_rec


def test_maintain_keeps_registered_snapshots_byte_stable():
    db = api.Uruv(_small_cfg(), policy=api.LifecyclePolicy(
        auto_maintain=False))
    rng = np.random.default_rng(2)
    keys = rng.choice(5000, 800, replace=False).astype(np.int32)
    db.insert(keys, keys % 211 + 1)
    snap = db.acquire_snapshot()
    probe = np.arange(0, 5000, 3, dtype=np.int32)
    look0 = db.lookup(probe, snap)
    range0 = db.range(0, 4999, snap)
    # interleave updates (incl. deletes of snapshotted keys) + maintenance
    db.delete(keys[::2])
    db.insert(keys[1::4] + 1, 7)
    for p in range(8):
        db.maintain(64, phase=p)
    db.grow(leaves=True, versions=True)
    np.testing.assert_array_equal(db.lookup(probe, snap), look0)
    assert db.range(0, 4999, snap) == range0
    db.release_snapshot(snap)
    # with the registration gone the floor advances: maintenance now
    # purges the tombstoned keys PHYSICALLY (pool occupancy drops) while
    # live contents and current-clock reads are untouched
    lk0 = LC.live_key_count(db.store)
    want_live = db.live_items()
    now = db.ts
    for p in range(8):
        db.maintain(64, phase=p)
    assert LC.live_key_count(db.store) < lk0
    assert db.live_items() == want_live
    # purged keys stay gone (excluding ones the later insert resurrected)
    reinserted = set((keys[1::4] + 1).tolist())
    purged = np.array([k for k in keys[::2].tolist()
                       if k not in reinserted], np.int32)
    assert len(purged) and all(
        v == api.NOT_FOUND for v in db.lookup(purged, now))


# ---------------------------------------------------------------------------
# the capacity-pressure property test (acceptance)
# ---------------------------------------------------------------------------

def test_sustained_crud_grows_past_8x_with_oracle():
    """>8x the seed leaf pool through repro.api: zero CapacityError,
    RefStore equivalence throughout, accounting invariant, snapshot
    stability across interleaved automatic maintenance."""
    cfg = _small_cfg()                     # 64-leaf seed pool
    db = api.Uruv(cfg)                     # DEFAULT policy: self-sizing
    ref = RefStore()
    rng = np.random.default_rng(3)
    width = 128
    snap = None
    snap_expect = None
    probe = np.arange(0, 400_000, 1013, dtype=np.int32)
    for rnd in range(70):
        r = rng.random(width)
        codes = np.where(r < 0.7, OP_INSERT,
                         np.where(r < 0.85, OP_DELETE,
                                  OP_SEARCH)).astype(np.int32)
        keys = rng.integers(0, 400_000, width).astype(np.int32)
        vals = (keys % 1000 + 1).astype(np.int32)
        res = db.apply(api.OpBatch(codes, keys, vals))
        want = ref.apply_batch([(int(c), int(k), int(v))
                                for c, k, v in zip(codes, keys, vals)])
        np.testing.assert_array_equal(np.asarray(res.values), want)
        if rnd % 10 == 0:
            _assert_accounting(db.store)
        if rnd == 30:                      # pin a mid-run snapshot
            snap = db.acquire_snapshot()
            snap_expect = db.lookup(probe, snap)
    assert db.capacity.max_leaves >= 8 * cfg.max_leaves, (
        f"grew only to {db.capacity.max_leaves}")
    assert int(np.asarray(db.store.n_alloc)) > 8 * cfg.max_leaves // 2
    assert db.stats["grows"] >= 3
    assert db.stats["leaves_reclaimed"] > 0, "maintenance never interleaved"
    # the pinned snapshot survived every grow/maintain since round 30
    np.testing.assert_array_equal(db.lookup(probe, snap), snap_expect)
    db.release_snapshot(snap)
    assert db.live_items() == ref.live_items()
    S.check_invariants(db.store)
    _assert_accounting(db.store)
    # and ranges still match the oracle at the final clock
    with db.snapshot() as ts:
        assert db.range(0, 400_000, ts) == ref.range_query(0, 400_000,
                                                           ref.ts)


def test_held_snapshot_survives_tracker_churn_and_maintain():
    """Regression: the tracker ring must never evict a HELD registration
    while free slots exist — transient snapshot/release churn past
    tracker_cap used to wrap the cursor onto the held slot, after which
    maintenance purged data the snapshot could still read."""
    db = api.Uruv(_small_cfg(tracker_cap=8))
    keys = np.arange(100, dtype=np.int32)
    db.insert(keys, keys + 41)
    held = db.acquire_snapshot()
    want = db.lookup(keys, held)
    assert int(want[0]) == 41
    for _ in range(3 * db.capacity.tracker_cap):   # churn: register+release
        with db.snapshot():
            pass
    db.delete(keys)                                 # tombstones after held
    for p in range(6):
        db.maintain(64, phase=p)
    assert db.active_snapshots >= 1                 # registration survived
    np.testing.assert_array_equal(db.lookup(keys, held), want)
    db.release_snapshot(held)


def test_tracker_grows_instead_of_dropping_registrations():
    db = api.Uruv(_small_cfg(tracker_cap=4))
    db.insert([1, 2, 3], [10, 20, 30])
    snaps = [db.acquire_snapshot() for _ in range(9)]
    assert db.capacity.tracker_cap >= 9
    assert int(np.asarray(db.store.oflow)) & S.OFLOW_TRACKER == 0
    assert db.active_snapshots == 9
    for s in snaps:
        db.release_snapshot(s)
    assert db.active_snapshots == 0


# ---------------------------------------------------------------------------
# CapacityError diagnostics (growth disabled)
# ---------------------------------------------------------------------------

def test_capacity_error_diagnostics_when_growth_disabled():
    tiny = api.UruvConfig(leaf_cap=4, max_leaves=8, max_versions=64,
                          max_chain=8)
    db = api.Uruv(tiny, policy=api.LifecyclePolicy(auto_grow=False,
                                                   auto_maintain=False))
    keys = np.arange(0, 64, dtype=np.int32)
    with pytest.raises(api.CapacityError) as ei:
        for i in range(0, 64, 8):
            db.apply(api.OpBatch.inserts(keys[i:i + 8], keys[i:i + 8]))
    err = ei.value
    assert err.oflow & (S.OFLOW_LEAVES | S.OFLOW_VERSIONS)
    assert err.occupancy > 0.5
    assert 0.0 <= err.frozen_fraction <= 1.0
    assert err.max_versions == 64
    assert "occupancy=" in str(err)
    # the same workload under the default policy completes
    db2 = api.Uruv(tiny)
    for i in range(0, 64, 8):
        db2.apply(api.OpBatch.inserts(keys[i:i + 8], keys[i:i + 8]))
    assert len(db2.live_items()) == 64


# ---------------------------------------------------------------------------
# checkpoint round-trip across capacity changes
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_across_grow(tmp_path):
    from repro.checkpoint.manager import CheckpointManager

    mgr = CheckpointManager(str(tmp_path), async_write=False)
    db = api.Uruv(_small_cfg())
    ref = RefStore()
    _ingest(db, ref, np.random.default_rng(4), 3, width=48, universe=2000)
    mgr.save_store(db.store, step=1)

    _ingest(db, ref, np.random.default_rng(5), 20, width=96,
            universe=100_000)
    assert db.capacity.max_leaves > _small_cfg().max_leaves  # grew
    mgr.save_store(db.store, step=2)

    for step, want in ((1, None), (2, db.store)):
        got, got_step = mgr.restore_store(step=step)
        assert got_step == step
        if want is not None:
            assert got.cfg == want.cfg
            for (pa, a), (pb, b) in zip(
                    jax.tree_util.tree_flatten_with_path(got)[0],
                    jax.tree_util.tree_flatten_with_path(want)[0]):
                np.testing.assert_array_equal(
                    np.asarray(a), np.asarray(b), err_msg=str(pa))
    # the step-1 restore carries the PRE-growth capacities and is usable
    got1, _ = mgr.restore_store(step=1)
    assert got1.cfg.max_leaves == _small_cfg().max_leaves
    S.check_invariants(got1)
    # the step-2 restore matches the live client's contents
    got2, _ = mgr.restore_store(step=2)
    assert api.Uruv.from_store(got2).live_items() == db.live_items()

    # stacked (sharded-shaped) stores round-trip too
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (2,) + x.shape), db.store)
    mgr.save_store(stacked, step=3)
    got3, _ = mgr.restore_store(step=3)
    assert np.asarray(got3.ts).shape == (2,)
    np.testing.assert_array_equal(np.asarray(got3.leaf_keys),
                                  np.asarray(stacked.leaf_keys))


# ---------------------------------------------------------------------------
# sharded lifecycle == local, bit-identical (4 fake devices, subprocess)
# ---------------------------------------------------------------------------

SHARDED_LIFECYCLE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "src")
import numpy as np, jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro import api
from repro.core.ref import OP_INSERT, OP_DELETE, OP_SEARCH
from repro.core import lifecycle as LC

mesh = make_mesh((4,), ("data",))
base = api.UruvConfig(leaf_cap=16, max_leaves=16, max_versions=1 << 10,
                      tracker_cap=8, max_chain=16)
scfg = api.ShardedConfig(base=base, key_lo=0, key_hi=40_000)
sdb = api.Uruv.sharded(scfg, mesh)
ldb = api.Uruv(base)
rng = np.random.default_rng(7)
W = 32
for rnd in range(26):
    r = rng.random(W)
    codes = np.where(r < 0.6, OP_INSERT,
                     np.where(r < 0.8, OP_DELETE, OP_SEARCH)).astype(np.int32)
    keys = rng.integers(0, 40_000, W).astype(np.int32)
    vals = (keys % 1000 + 1).astype(np.int32)
    plan = api.OpBatch(codes, keys, vals)
    rs = sdb.apply(plan)
    rl = ldb.apply(plan)
    np.testing.assert_array_equal(np.asarray(rs.values),
                                  np.asarray(rl.values))
    np.testing.assert_array_equal(np.asarray(rs.timestamps),
                                  np.asarray(rl.timestamps))
    assert sdb.ts == ldb.ts, (sdb.ts, ldb.ts)
# BOTH topologies outgrew the seed pools (per-shard AND local)
assert sdb.capacity.max_leaves > base.max_leaves, sdb.capacity
assert ldb.capacity.max_leaves > base.max_leaves, ldb.capacity
assert sdb.stats["grows"] > 0 and ldb.stats["grows"] > 0
# every shard shares one shape and the replicated clock agrees
assert np.unique(np.asarray(sdb.store.ts)).size == 1
# reads at a sweep of HISTORIC snapshots are bit-identical (version
# timestamps resolve identically) even though the two topologies ran
# different grow/maintain schedules
probe = np.arange(0, 40_000, 61, dtype=np.int32)
for snap in range(0, ldb.ts, max(1, ldb.ts // 7)):
    np.testing.assert_array_equal(
        np.asarray(sdb.lookup(probe, snap)),
        np.asarray(ldb.lookup(probe, snap)))
assert sorted(sdb.live_items()) == sorted(ldb.live_items())
# explicit vmapped maintenance on the stacked store stays byte-stable
with sdb.snapshot() as ts:
    before = sdb.range(0, 40_000, ts)
    sdb.maintain(64, phase=0)
    sdb.maintain(64, phase=1)
    after = sdb.range(0, 40_000, ts)
assert before == after
for sh in range(4):
    shard = jax.tree.map(lambda x: x[sh], sdb.store)
    from repro.core import store as S
    S.check_invariants(shard)
acc = LC.leaf_accounting(sdb.store)
assert acc["n_alloc"] == acc["live"] + acc["dead"], acc
print("SHARDED_LIFECYCLE_OK")
"""


def test_sharded_lifecycle_matches_local_on_4_devices():
    r = subprocess.run(
        [sys.executable, "-c", SHARDED_LIFECYCLE_SCRIPT],
        capture_output=True, text=True, timeout=900,
        cwd=str(Path(__file__).resolve().parents[1]),
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "SHARDED_LIFECYCLE_OK" in r.stdout
