"""Oracle self-consistency + baseline (FlatStore) behaviour."""

import numpy as np
import jax.numpy as jnp

from repro.core import baseline as BL
from repro.core.ref import (
    NOT_FOUND, TOMBSTONE, OP_DELETE, OP_INSERT, OP_SEARCH, RefStore,
)


def test_ref_basic_adt():
    r = RefStore()
    r.insert(5, 50)
    r.insert(3, 30)
    assert r.search(5) == 50
    assert r.search(9) == NOT_FOUND
    assert r.delete(5)
    assert r.search(5) == NOT_FOUND
    assert not r.delete(5)              # already tombstoned
    assert r.range_query(0, 10) == [(3, 30)]


def test_ref_snapshot_reads():
    r = RefStore()
    r.insert(1, 10)
    snap = r.snapshot()
    r.insert(1, 11)
    r.insert(2, 20)
    assert r.search_at(1, snap) == 10
    assert r.search_at(2, snap) == NOT_FOUND
    assert r.range_query(0, 5, snap) == [(1, 10)]
    assert r.range_query(0, 5) == [(1, 11), (2, 20)]
    r.release(snap)


def test_ref_compact_respects_tracker():
    r = RefStore()
    r.insert(1, 10)
    snap = r.snapshot()
    r.insert(1, 11)
    r.delete(2)
    r.compact()
    assert r.search_at(1, snap) == 10   # retained: snapshot active
    r.release(snap)
    n = r.compact()
    assert n > 0
    assert r.search(1) == 11


def test_ref_batch_timestamps():
    r = RefStore()
    res = r.apply_batch([
        (OP_INSERT, 1, 10), (OP_SEARCH, 1, 0), (OP_DELETE, 1, 0),
        (OP_SEARCH, 1, 0),
    ])
    assert res == [NOT_FOUND, 10, 10, NOT_FOUND]
    assert r.ts == 4


def test_flat_baseline_not_linearizable_under_updates():
    """The baseline's unvalidated scan can observe a mixed (torn) state;
    the validated scan retries — the cost Uruv's MVCC avoids."""
    b = BL.create(256)
    keys = np.arange(10, dtype=np.int32)
    b = BL.bulk_update(b, jnp.asarray(keys), jnp.asarray(keys * 10))

    versions = [b]
    # a concurrent updater flips all values between the two scans
    def store_ref():
        if len(versions) == 1:
            versions.append(BL.bulk_update(
                versions[0], jnp.asarray(keys),
                jnp.asarray(keys * 10 + 1)))
            return versions[0]
        return versions[-1]

    res, scans = BL.range_query_validated(store_ref, 0, 9, max_results=32)
    assert scans >= 2                   # needed at least one retry
    vals = [v for _, v in res]
    assert vals == (keys * 10 + 1).tolist()
