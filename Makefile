.PHONY: check lint test test-slow test-range api examples docs bench-kernels \
	bench-mixed bench-range bench-lifecycle bench-index bench-serve bench-wal

check:
	bash scripts/check.sh

# uruvlint: the repo's structural invariants as AST static analysis —
# layering, @device_pass purity, donation safety, determinism, kernel
# parity/VMEM, sentinel-literal confinement (DESIGN.md Sec 13).
# `make lint FORMAT=json` emits the machine-diffable report.
FORMAT ?= text
lint:
	PYTHONPATH=src python -m repro.analysis --format=$(FORMAT) \
		src/repro benchmarks examples scripts

test:
	PYTHONPATH=src python -m pytest -x -q

# the slow-marked batteries excluded from tier-1: large-pool growth and
# the full kill -9 crash-recovery sweep (tests/test_wal_recovery.py)
test-slow:
	PYTHONPATH=src python -m pytest -x -q -m slow

test-range:
	PYTHONPATH=src python -m pytest -x -q tests/test_range_property.py \
		tests/test_kernels.py tests/test_sharding_dist.py

# the public repro.api surface: OpBatch/Result/client/executors battery
api:
	PYTHONPATH=src python -m pytest -x -q tests/test_api.py

# all examples, routed through the Pallas interpret backend; fails on any
# DeprecationWarning raised from inside src/repro (internals must be
# fully migrated onto repro.api)
examples:
	PYTHONPATH=src python scripts/run_examples.py

bench-kernels:
	PYTHONPATH=src python -m benchmarks.run --quick --only kernels

bench-mixed:
	PYTHONPATH=src python -m benchmarks.run --quick --only mixed

bench-range:
	PYTHONPATH=src python -m benchmarks.run --quick --only range

# self-sizing lifecycle: incremental maintain vs stop-the-world compact,
# grow amortization; writes BENCH_lifecycle.json
bench-lifecycle:
	PYTHONPATH=src python -m benchmarks.run --quick --only lifecycle

# multi-level fat-node index: delta maintenance vs flat full-rebuild,
# locate at depth 1 vs multi-level; writes BENCH_index.json
bench-index:
	PYTHONPATH=src python -m benchmarks.run --quick --only index

# pipelined serving front end: closed-loop tail-latency matrix
# (zipf/uniform mixes, p50/p95/p99 per op, saturation throughput vs the
# synchronous per-request baseline); writes BENCH_serve.json
bench-serve:
	PYTHONPATH=src python -m benchmarks.run --quick --only serve

# durability: group-commit WAL ingest vs fsync-per-plan, delta vs full
# checkpoint bytes (gate: delta <= 25% of full), crash-recovery replay
# throughput (gate: >= 50k ops/s); writes BENCH_wal.json
bench-wal:
	PYTHONPATH=src python -m benchmarks.run --quick --only wal

# extract + run every fenced ```python block in README.md / DESIGN.md
# under URUV_BACKEND=pallas_interpret (docs can never rot)
docs:
	PYTHONPATH=src python scripts/check_docs.py
