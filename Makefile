.PHONY: check test test-range bench-kernels bench-mixed bench-range

check:
	bash scripts/check.sh

test:
	PYTHONPATH=src python -m pytest -x -q

test-range:
	PYTHONPATH=src python -m pytest -x -q tests/test_range_property.py \
		tests/test_kernels.py tests/test_sharding_dist.py

bench-kernels:
	PYTHONPATH=src python -m benchmarks.run --quick --only kernels

bench-mixed:
	PYTHONPATH=src python -m benchmarks.run --quick --only mixed

bench-range:
	PYTHONPATH=src python -m benchmarks.run --quick --only range
