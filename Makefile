.PHONY: check test bench-kernels bench-mixed

check:
	bash scripts/check.sh

test:
	PYTHONPATH=src python -m pytest -x -q

bench-kernels:
	PYTHONPATH=src python -m benchmarks.run --quick --only kernels

bench-mixed:
	PYTHONPATH=src python -m benchmarks.run --quick --only mixed
