"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (derived = Mops/s for ADT
workloads; see each section).  Figures:

  * fig8 a–c   — dictionary workloads, Uruv vs the flat-chunk baseline
                 (the paper's LF-B+Tree/OpenBw-Tree role), sweeping the
                 announce width (the paper's thread-count axis).
  * fig9 a–f   — range-query mixes, Uruv MVCC snapshot scans vs
                 validate-retry multi-scan (the paper's VCAS-BST role).
  * table_complexity — measured wait-free bound: passes per op vs
                 conflict concentration (the paper's m = f(I_C) bound).
  * kernels    — Uruv hot-path kernels, XLA path (CPU relative numbers).
  * mixed      — the fused one-pass ``bulk_apply`` vs the pre-fusion
                 two-pass path (update pass + host sync + lookup pass)
                 on a mixed announce array; writes BENCH_mixed.json.
  * range      — the batched device-resident ``bulk_range`` (Q intervals,
                 ONE jitted pass) vs the host-paginated per-query
                 ``range_query`` loop; writes BENCH_range.json.
  * lifecycle  — self-sizing store costs: incremental ``maintain`` vs
                 stop-the-world ``compact`` at matched reclamation, and
                 auto-grow amortization vs a pre-sized pool; writes
                 BENCH_lifecycle.json.
  * index      — multi-level fat-node index: structural-batch latency
                 under delta maintenance vs the flat full-rebuild
                 discipline (4k -> 64k leaves), and locate throughput at
                 depth 1 vs multi-level; writes BENCH_index.json.
  * serve      — closed-loop tail-latency matrix for the pipelined
                 admission front end (zipf/uniform x CRUD/range mixes,
                 per-op p50/p95/p99 + saturation throughput vs the
                 synchronous per-request baseline, 10k-deep burst
                 drain); writes BENCH_serve.json.
  * wal        — durability: group-commit WAL ingest vs fsync-per-plan,
                 delta vs full checkpoint bytes + latency, and crash-
                 recovery replay throughput (gated: delta <= 25% of the
                 full save, replay >= 50k ops/s); writes BENCH_wal.json.

Run: PYTHONPATH=src python -m benchmarks.run [--quick]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

import numpy as np

from benchmarks import workloads as W
from repro.api import (
    KEY_MAX, NOT_FOUND, TOMBSTONE, OP_DELETE, OP_INSERT, OP_SEARCH,
    LifecyclePolicy, OpBatch, Uruv, UruvConfig,
)

WIDTHS = [64, 256, 1024, 4096]


def emit(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.2f},{derived}", flush=True)


def fig8(quick: bool = False) -> None:
    rng = np.random.default_rng(0)
    uruv = W.prefill_uruv(rng)
    flat = W.prefill_flat(rng)
    widths = WIDTHS[:2] if quick else WIDTHS
    for name, w in W.FIG8.items():
        for width in widths:
            uruv, sec = W.run_uruv(uruv, rng, w, width)
            emit(f"{name}_uruv_w{width}", sec * 1e6,
                 f"{width/sec/1e6:.3f}Mops/s")
            flat, fsec = W.run_flat(flat, rng, w, width)
            emit(f"{name}_flatbase_w{width}", fsec * 1e6,
                 f"{width/fsec/1e6:.3f}Mops/s")


def fig9(quick: bool = False) -> None:
    rng = np.random.default_rng(1)
    uruv = W.prefill_uruv(rng)
    flat = W.prefill_flat(rng)
    widths = [1024] if quick else [1024, 4096]
    figs = dict(list(W.FIG9.items())[:2]) if quick else W.FIG9
    for name, w in figs.items():
        for width in widths:
            uruv, sec = W.run_uruv(uruv, rng, w, width)
            emit(f"{name}_uruv_w{width}", sec * 1e6,
                 f"{width/sec/1e6:.3f}Mops/s")
            flat, fsec = W.run_flat(flat, rng, w, width)
            emit(f"{name}_validate_retry_w{width}", fsec * 1e6,
                 f"{width/fsec/1e6:.3f}Mops/s")


def table_complexity() -> None:
    """Wait-free bound: slow-path rounds vs conflict concentration.

    The paper bounds restarts by m = min(f + s*t, I_C) (interval
    contention).  The batch analogue: a prefilled store receives 1024 NEW
    keys concentrated in a span of the key space — the narrower the span,
    the more structural inserts collide on the same leaves and the more
    bounded help-rounds the combining layer runs.  Wide spans take the
    fast path (1 round) — observable through the client's device-pass
    counter (``Uruv.stats``)."""
    rng = np.random.default_rng(2)
    base_keys = rng.choice(1_000_000, 100_000, replace=False) \
        .astype(np.int32) * 2           # even keys prefilled
    for span in (1_000_000, 65_536, 8_192, 2_048):
        db = Uruv(UruvConfig(leaf_cap=16, max_leaves=1 << 15,
                             max_versions=1 << 19))
        for i in range(0, 100_000, 4096):
            db.apply(OpBatch.updates(base_keys[i:i+4096],
                                     base_keys[i:i+4096]))
        new = (rng.choice(span // 2, 1024, replace=False)
               .astype(np.int32) * 2 + 1)      # odd keys: all new
        before = db.stats["device_passes"]
        db.apply(OpBatch.updates(new, new))
        passes = db.stats["device_passes"] - before
        emit(f"complexity_span{span}_passes", float(passes),
             f"{passes}rounds")


def kernels(quick: bool = False) -> None:
    rng = np.random.default_rng(3)
    db = W.prefill_uruv(rng)
    q = rng.integers(0, W.UNIVERSE, 4096).astype(np.int32)
    ts = db.ts
    sec = W.timed(lambda: db.lookup(q, ts))    # np round-trip == block
    emit("kernel_locate_resolve_4096", sec * 1e6,
         f"{4096/sec/1e6:.2f}Mlookups/s")
    sec = W.timed(lambda: db.scan_page(
        100_000, 101_000, ts, max_scan_leaves=64,
        max_results=2048).keys.block_until_ready())
    emit("kernel_range1k_snapshot", sec * 1e6, "1scan")


MIXED_CFG = UruvConfig(leaf_cap=64, max_leaves=1 << 13,
                       max_versions=1 << 19, max_chain=64)
MIXED_RESIDENT = 200_000


def _two_pass_apply(db: Uruv, codes, keys, vals):
    """The pre-bulk_apply execution path (seed `batch.apply_batch`): one
    device pass for INSERT/DELETE, a host sync, a second device pass for
    SEARCH at per-op snapshots, host-side result assembly.  The update pass
    runs with ``light_path=False`` — the seed rebuilt the structure
    unconditionally (validated against the actual seed checkout)."""
    n = len(codes)
    base = db.ts
    upd_mask = (codes == OP_INSERT) | (codes == OP_DELETE)
    ukeys = np.where(upd_mask, keys, KEY_MAX).astype(np.int32)
    uvals = np.where(codes == OP_DELETE, TOMBSTONE, vals).astype(np.int32)
    rounds = db.stats["slow_path_rounds"]
    res_u = db.apply(OpBatch.updates(ukeys, uvals), light_path=False)
    assert db.stats["slow_path_rounds"] == rounds, \
        "baseline update pass rejected; resize MIXED_CFG"
    results = np.full(n, NOT_FOUND, np.int64)
    results[upd_mask] = res_u.values[upd_mask]
    smask = codes == OP_SEARCH
    skeys = np.where(smask, keys, KEY_MAX).astype(np.int32)
    snaps = (base + np.arange(n)).astype(np.int32)
    results[smask] = db.lookup(skeys, snaps)[smask]
    return results


def mixed(quick: bool = False, out_path: str = "BENCH_mixed.json") -> None:
    """Fused mixed-op pass vs the old two-pass path (DESIGN.md Sec 3).

    Workload: 90% SEARCH / 5% INSERT / 5% DELETE over a resident working
    set (updates overwrite live keys — the serving-table traffic pattern).
    Both paths run through the `repro.api` client and produce bit-identical
    announce-order results; the fused path is ONE device call per batch
    (asserted via the client's device-pass counter)."""
    rng = np.random.default_rng(5)
    db0 = Uruv(MIXED_CFG)
    resident = rng.choice(W.UNIVERSE, MIXED_RESIDENT,
                          replace=False).astype(np.int32)
    for i in range(0, MIXED_RESIDENT, 4096):
        db0.apply(OpBatch.updates(resident[i:i+4096],
                                  resident[i:i+4096] % 1000 + 1))
    widths = [1024] if quick else [1024, 4096]
    report = {}
    for width in widths:
        batches = []
        for _ in range(4):
            r = rng.random(width)
            codes = np.where(
                r < 0.90, OP_SEARCH,
                np.where(r < 0.95, OP_INSERT, OP_DELETE),
            ).astype(np.int32)
            keys = resident[rng.integers(0, MIXED_RESIDENT, width)] \
                .astype(np.int32)
            vals = (keys % 1000 + 1).astype(np.int32)
            batches.append((codes, keys, vals))

        # the two paths must agree before we time them — and the fused
        # client path must stay ONE device pass (the PR-1 guard)
        db_chk = Uruv.from_store(db0.store)
        passes = db_chk.stats["device_passes"]
        res_f = db_chk.apply(OpBatch(*batches[0]))
        assert db_chk.stats["device_passes"] == passes + 1, \
            "client fast path issued more than one device pass"
        db_chk2 = Uruv.from_store(db0.store)
        res_t = _two_pass_apply(db_chk2, *batches[0])
        assert res_f.values.tolist() == res_t.tolist(), \
            "fused and two-pass paths disagree"

        db_f = Uruv.from_store(db0.store)

        def run_fused():
            for c, k, v in batches:
                db_f.apply(OpBatch(c, k, v))

        fsec = W.timed(run_fused) / len(batches)

        db_t = Uruv.from_store(db0.store)

        def run_two_pass():
            for c, k, v in batches:
                _two_pass_apply(db_t, c, k, v)

        tsec = W.timed(run_two_pass) / len(batches)
        emit(f"mixed_fused_w{width}", fsec * 1e6,
             f"{width/fsec/1e6:.3f}Mops/s")
        emit(f"mixed_two_pass_w{width}", tsec * 1e6,
             f"{width/tsec/1e6:.3f}Mops/s")
        emit(f"mixed_speedup_w{width}", tsec / fsec, f"{tsec/fsec:.2f}x")
        report[f"w{width}"] = {
            "fused_us": round(fsec * 1e6, 1),
            "two_pass_us": round(tsec * 1e6, 1),
            "speedup": round(tsec / fsec, 2),
        }
    Path(out_path).write_text(json.dumps(report, indent=2) + "\n")


RANGE_CFG = UruvConfig(leaf_cap=64, max_leaves=1 << 13,
                       max_versions=1 << 19, max_chain=64)
RANGE_RESIDENT = 100_000
RANGE_UNIVERSE = 1_000_000


def _host_paged_ranges(db: Uruv, k1s, k2s, ts, *, max_scan_leaves,
                       max_results):
    """The pre-bulk_range serving shape: one jitted `scan_page` call per
    interval, host sync per page, resume from last key + 1 (the seed
    `range_query_all` loop, batched over queries by a host for-loop)."""
    out = []
    for a, b in zip(k1s, k2s):
        lo, items = int(a), []
        while True:
            page = db.scan_page(lo, int(b), ts,
                                max_scan_leaves=max_scan_leaves,
                                max_results=max_results)
            cnt = int(page.count[0])
            k = np.asarray(page.keys)[0, :cnt]
            items.extend(zip(k.tolist(),
                             np.asarray(page.values)[0, :cnt].tolist()))
            if not bool(page.truncated[0]):
                break
            lo = int(k[-1]) + 1 if cnt else lo + 1
        out.append(items)
    return out


def range_bench(quick: bool = False, out_path: str = "BENCH_range.json") -> None:
    """Batched `bulk_range` vs the host-paginated per-query loop.

    Workload: Q mixed-width intervals (widths log-spread from point-ish
    scans to ~4k-key spans) over a 100k-key resident store — the serve
    engine's snapshot_view / data pipeline epoch-reader traffic.  Both
    paths return identical (key, value) pages; the fused path is ONE
    device call for all Q queries (in-pass pagination)."""
    rng = np.random.default_rng(7)
    db = Uruv(RANGE_CFG)
    resident = rng.choice(RANGE_UNIVERSE, RANGE_RESIDENT,
                          replace=False).astype(np.int32)
    for i in range(0, RANGE_RESIDENT, 4096):
        db.apply(OpBatch.updates(resident[i:i+4096],
                                 resident[i:i+4096] % 1000 + 1))
    ts = db.ts
    # both Q points always run (the acceptance evidence in BENCH_range.json
    # covers Q=64 and Q=256); quick mode trims the timing repeats instead
    qs = [64, 256]
    repeats = (3, 1) if quick else (5, 2)
    widths = np.array([100, 1_000, 10_000, 40_000])     # mixed-width mix
    report = {}
    for Q in qs:
        k1 = rng.integers(0, RANGE_UNIVERSE - 50_000, Q).astype(np.int32)
        k2 = (k1 + widths[np.arange(Q) % len(widths)]).astype(np.int32)

        # the two paths must agree before we time them
        pages = db.range_all(k1, k2, ts, max_results=4096,
                             scan_leaves=32, max_rounds=1)
        paged = _host_paged_ranges(db, k1, k2, ts,
                                   max_scan_leaves=128, max_results=4096)
        assert pages == paged, "bulk_range and host-paginated loop disagree"

        def run_bulk():
            db.range_all(k1, k2, ts, max_results=4096,
                         scan_leaves=32, max_rounds=1)

        bsec = W.timed(run_bulk, repeats=repeats[0], warmup=1)

        def run_paged():
            _host_paged_ranges(db, k1, k2, ts,
                               max_scan_leaves=128, max_results=4096)

        psec = W.timed(run_paged, repeats=repeats[1], warmup=1)
        emit(f"range_bulk_q{Q}", bsec * 1e6, f"{Q/bsec/1e3:.2f}Kq/s")
        emit(f"range_host_paged_q{Q}", psec * 1e6, f"{Q/psec/1e3:.2f}Kq/s")
        emit(f"range_speedup_q{Q}", psec / bsec, f"{psec/bsec:.2f}x")
        report[f"q{Q}"] = {
            "bulk_us": round(bsec * 1e6, 1),
            "host_paged_us": round(psec * 1e6, 1),
            "speedup": round(psec / bsec, 2),
        }
    Path(out_path).write_text(json.dumps(report, indent=2) + "\n")


def lifecycle_bench(quick: bool = False,
                    out_path: str = "BENCH_lifecycle.json") -> None:
    """Self-sizing lifecycle costs (DESIGN.md Sec 10); BENCH_lifecycle.json.

    (a) *Incremental maintain vs stop-the-world compact* at matched
    reclamation: a store is driven to heavy garbage (frozen split-leavings
    from sustained ingest + tombstones from a bulk delete), then the same
    start state is reclaimed two ways — bounded ``maintain`` passes until
    quiescence vs ONE ``compact()`` — and we report total time, per-pass
    pause, and us per reclaimed leaf slot.  ``maintain``'s per-pass pause
    is the serving-relevant number: it bounds the latency a reclamation
    step can inject into an admission path.

    (b) *Grow amortization*: ingest a working set that is ~32x the initial
    leaf pool with auto-grow on, vs the same ingest into a pre-sized pool;
    the delta is the total cost of all grow events + regrowth recompiles.
    """
    import time as _time

    rng = np.random.default_rng(11)
    ML0 = 1 << 10 if quick else 1 << 12
    n_keys = (ML0 * 24)                     # ~75% of pool after splits
    cfg = UruvConfig(leaf_cap=32, max_leaves=ML0, max_versions=1 << 18,
                     max_chain=64)
    manual = LifecyclePolicy(auto_grow=True, auto_maintain=False)
    db = Uruv(cfg, policy=manual)
    keys = rng.choice(20_000_000, n_keys, replace=False).astype(np.int32)
    for i in range(0, n_keys, 2048):
        db.apply(OpBatch.inserts(keys[i:i + 2048], keys[i:i + 2048] % 997 + 1))
    dels = keys[rng.random(n_keys) < 0.6]
    for i in range(0, len(dels), 2048):
        db.apply(OpBatch.deletes(dels[i:i + 2048]))
    s0 = db.store
    n_alloc0 = int(np.asarray(s0.n_alloc))
    budget = 256
    report = {}

    def drain(store):
        """Maintain to quiescence -> (store, reclaimed, passes, max_pause_s)."""
        from repro.api import LocalExecutor
        ex = LocalExecutor(store.cfg, policy=manual)
        total = passes = 0
        max_pause = 0.0
        while True:
            t0 = _time.perf_counter()
            store, rec, mer = ex.maintain(store, budget, phase=passes)
            max_pause = max(max_pause, _time.perf_counter() - t0)
            total += rec
            passes += 1
            if (rec == 0 and mer == 0) or passes > 256:
                break
        return store, total, passes, max_pause

    drain(s0)                                # warmup (compiles)
    times, recs, pauses, npasses = [], [], [], []
    for _ in range(2 if quick else 3):
        t0 = _time.perf_counter()
        _, rec, passes, pause = drain(s0)
        times.append(_time.perf_counter() - t0)
        recs.append(rec)
        pauses.append(pause)
        npasses.append(passes)
    m_us = float(np.min(times)) * 1e6
    m_rec = recs[0]

    db_c = Uruv.from_store(s0, policy=manual)
    db_c.compact()                           # warmup (compiles)
    ctimes, crecs = [], []
    for _ in range(2 if quick else 3):
        db_c = Uruv.from_store(s0, policy=manual)
        t0 = _time.perf_counter()
        db_c.compact()
        ctimes.append(_time.perf_counter() - t0)
        crecs.append(n_alloc0 - int(np.asarray(db_c.store.n_alloc)))
    c_us = float(np.min(ctimes)) * 1e6
    c_rec = crecs[0]

    m_per_leaf = m_us / max(m_rec, 1)
    c_per_leaf = c_us / max(c_rec, 1)
    emit("lifecycle_maintain_total", m_us,
         f"{m_rec}leaves/{npasses[0]}passes")
    emit("lifecycle_maintain_max_pause", pauses[0] * 1e6, "1pass")
    emit("lifecycle_compact_total", c_us, f"{c_rec}leaves/1pass")
    emit("lifecycle_us_per_leaf_speedup", c_per_leaf / m_per_leaf,
         f"{c_per_leaf / m_per_leaf:.2f}x")
    report["maintain_vs_compact"] = {
        "start_n_alloc": n_alloc0,
        "maintain_total_us": round(m_us, 1),
        "maintain_reclaimed": m_rec,
        "maintain_passes": npasses[0],
        "maintain_max_pause_us": round(pauses[0] * 1e6, 1),
        "compact_total_us": round(c_us, 1),
        "compact_reclaimed": c_rec,
        "maintain_us_per_leaf": round(m_per_leaf, 2),
        "compact_us_per_leaf": round(c_per_leaf, 2),
        "speedup_us_per_leaf": round(c_per_leaf / m_per_leaf, 2),
    }

    # ---- (b) grow amortization: auto-grown vs pre-sized ingest ----------
    g_keys = rng.choice(20_000_000, 1 << (14 if quick else 16),
                        replace=False).astype(np.int32)
    small = UruvConfig(leaf_cap=32, max_leaves=256, max_versions=1 << 12,
                       max_chain=64)

    def ingest(config):
        dbi = Uruv(config)
        t0 = _time.perf_counter()
        for i in range(0, len(g_keys), 2048):
            dbi.apply(OpBatch.inserts(g_keys[i:i + 2048],
                                      g_keys[i:i + 2048] % 997 + 1))
        return _time.perf_counter() - t0, dbi

    ingest(small)                            # warmup (compiles every bucket)
    g_sec, dbg = ingest(small)
    big = UruvConfig(leaf_cap=32, max_leaves=dbg.capacity.max_leaves,
                     max_versions=dbg.capacity.max_versions, max_chain=64)
    ingest(big)                              # warmup
    p_sec, _ = ingest(big)
    overhead = (g_sec - p_sec) / p_sec
    emit("lifecycle_grow_ingest", g_sec * 1e6,
         f"{dbg.stats['grows']}grows")
    emit("lifecycle_presized_ingest", p_sec * 1e6, "0grows")
    emit("lifecycle_grow_overhead", overhead * 100, f"{overhead:+.1%}")
    report["grow_amortization"] = {
        "n_keys": len(g_keys),
        "initial_max_leaves": small.max_leaves,
        "final_max_leaves": dbg.capacity.max_leaves,
        "grows": dbg.stats["grows"],
        "auto_grow_ingest_us": round(g_sec * 1e6, 1),
        "presized_ingest_us": round(p_sec * 1e6, 1),
        "overhead_fraction": round(overhead, 3),
    }
    Path(out_path).write_text(json.dumps(report, indent=2) + "\n")


def index_bench(quick: bool = False,
                out_path: str = "BENCH_index.json") -> None:
    """Multi-level fat-node index costs (DESIGN.md Sec 11); BENCH_index.json.

    (a) *Structural maintenance, delta vs flat rebuild, vs leaf count*:
    ONE jitted index-maintenance pass for a heavy structural batch (128
    leaf splits) applied two ways — the bounded bottom-up separator
    delta (`index.apply_split_delta`, O(touched·F·depth) — the shipped
    path) vs the flat full-rebuild discipline (`reindex`: repack the
    whole index, the pre-Sec-11 O(ML) behaviour).  The delta pass stays
    ~flat as the leaf count grows 4k -> 64k while the rebuild scales
    with ML — the per-ML speedup is the acceptance evidence.  The
    end-to-end structural `apply` latency under both disciplines is
    reported alongside for context (it folds in the leaf/version pool
    writes common to both paths, so its ratio is structurally smaller).

    (b) *Locate throughput, depth 1 vs multi-level*: the same resident
    set indexed with one flat root fat node (fanout >= ML: descent is the
    directory-era O(P·ML) compare-reduce) vs the default multi-level tree
    (O(P·F·depth)).
    """
    import time as _time

    import jax
    import jax.numpy as jnp
    from repro.core import index as _index   # isolated-pass microbench only

    rng = np.random.default_rng(13)
    report = {"structural": {}, "locate": {}}
    mls = [1 << 12, 1 << 14] if quick else [1 << 12, 1 << 14, 1 << 16]
    n_batches = 4
    width = 1024
    for ML in mls:
        n_res = ML * 6                       # ~60-75% leaf occupancy
        mv = max(1 << 16, 1 << int(np.ceil(np.log2(n_res * 1.5))))
        cfg = UruvConfig(leaf_cap=16, max_leaves=ML, max_versions=mv,
                         max_chain=16)
        db0 = Uruv(cfg, policy=LifecyclePolicy(auto_grow=False,
                                               auto_maintain=False))
        resident = (np.arange(n_res, dtype=np.int64) * 2).astype(np.int32)
        perm = rng.permutation(n_res)
        for i in range(0, n_res, 4096):
            b = resident[perm[i:i + 4096]]
            db0.apply(OpBatch.inserts(b, b % 997 + 1))
        n_leaves = int(np.asarray(db0.store.n_leaves))

        # ---- isolated maintenance pass: delta vs flat rebuild ----------
        K = 128                              # splits per structural batch
        seps, leaves = _index.directory(db0.store.index, n_leaves)
        pos = rng.choice(n_leaves - 1, K, replace=False) + 1
        st0 = db0.store
        n_alloc0 = int(np.asarray(st0.n_alloc))
        d_valid = jnp.ones((K,), bool)
        d_gkey = jnp.asarray(seps[pos])
        d_old = jnp.asarray(leaves[pos])
        d_left = jnp.arange(K, dtype=jnp.int32) * 2 + n_alloc0
        d_right = d_left + 1
        d_rkey = jnp.asarray(seps[pos] + 1)

        @jax.jit
        def delta_pass(idx):
            new, oflow = _index.apply_split_delta(
                idx, d_valid, d_gkey, d_old, d_left, d_right, d_rkey)
            return new

        def rebuild_pass(idx):
            return _index.reindex(idx, st0.n_leaves, ML)    # jitted inside

        jax.block_until_ready(delta_pass(st0.index))        # compile
        jax.block_until_ready(rebuild_pass(st0.index))
        dsec = W.timed(
            lambda: jax.block_until_ready(delta_pass(st0.index)))
        rsec = W.timed(
            lambda: jax.block_until_ready(rebuild_pass(st0.index)))
        emit(f"index_delta_pass_ml{ML}", dsec * 1e6, f"{K}splits")
        emit(f"index_rebuild_pass_ml{ML}", rsec * 1e6, f"{n_leaves}leaves")
        emit(f"index_pass_speedup_ml{ML}", rsec / dsec, f"{rsec/dsec:.2f}x")

        fresh = rng.choice(n_res * 2, n_batches * width * 2,
                           replace=False).astype(np.int64)
        fresh = (fresh[fresh % 2 == 1][: n_batches * width]) \
            .astype(np.int32)                # odd keys: all structural
        batches = [fresh[i * width:(i + 1) * width]
                   for i in range(n_batches)]

        def run(rebuild_every_batch):
            db = Uruv.from_store(db0.store,
                                 policy=LifecyclePolicy(
                                     auto_grow=False, auto_maintain=False))
            for b in batches:                # warmup: compile both paths
                db.apply(OpBatch.inserts(b[:width], b[:width] % 997 + 1))
                if rebuild_every_batch:
                    db.reindex()
                break
            db = Uruv.from_store(db0.store,
                                 policy=LifecyclePolicy(
                                     auto_grow=False, auto_maintain=False))
            t0 = _time.perf_counter()
            for b in batches:
                db.apply(OpBatch.inserts(b, b % 997 + 1))
                if rebuild_every_batch:
                    db.reindex()
            jax_block(db.store)
            return (_time.perf_counter() - t0) / n_batches
        delta_s = min(run(False) for _ in range(2))
        rebuild_s = min(run(True) for _ in range(2))
        emit(f"index_apply_delta_ml{ML}", delta_s * 1e6,
             f"{n_leaves}leaves")
        emit(f"index_apply_rebuild_ml{ML}", rebuild_s * 1e6,
             f"{n_leaves}leaves")
        report["structural"][f"ml{ML}"] = {
            "n_leaves": n_leaves,
            "delta_pass_us": round(dsec * 1e6, 1),
            "flat_rebuild_pass_us": round(rsec * 1e6, 1),
            "pass_speedup": round(rsec / dsec, 2),
            "apply_delta_us": round(delta_s * 1e6, 1),
            "apply_rebuild_us": round(rebuild_s * 1e6, 1),
            "apply_speedup": round(rebuild_s / delta_s, 2),
        }

    # ---- (b) locate: depth 1 (flat compare-reduce) vs multi-level -------
    ML = 1 << 10
    n_res = ML * 6
    resident = (np.arange(n_res, dtype=np.int64) * 2).astype(np.int32)
    perm = rng.permutation(n_res)
    probes = resident[rng.integers(0, n_res, 4096)].astype(np.int32)
    for label, fanout, bwidth in (("depth1", ML, 512),
                                  ("multilevel", 16, 4096)):
        cfg = UruvConfig(leaf_cap=16, max_leaves=ML, max_versions=1 << 16,
                         max_chain=16, index_fanout=fanout)
        db = Uruv(cfg, policy=LifecyclePolicy(auto_grow=False,
                                              auto_maintain=False))
        for i in range(0, n_res, bwidth):
            b = resident[perm[i:i + bwidth]]
            db.apply(OpBatch.inserts(b, b % 997 + 1))
        depth = db.store.index.cfg.depth
        ts = db.ts
        sec = W.timed(lambda: db.lookup(probes, ts))
        emit(f"index_locate_{label}", sec * 1e6,
             f"depth{depth};{len(probes)/sec/1e6:.2f}Mlookups/s")
        report["locate"][label] = {
            "depth": depth, "fanout": fanout,
            "us_per_4096": round(sec * 1e6, 1),
            "mlookups_per_s": round(len(probes) / sec / 1e6, 2),
        }
    Path(out_path).write_text(json.dumps(report, indent=2) + "\n")


def jax_block(tree) -> None:
    import jax

    jax.block_until_ready(tree)


def roofline_summary() -> None:
    """Dry-run roofline: dominant term for the hillclimbed cells (full
    table in EXPERIMENTS.md; reads experiments/dryrun artifacts)."""
    from pathlib import Path
    from repro.launch.roofline import analyze_cell

    cells = [
        ("llama3_2_1b", "decode_32k", "single"),
        ("olmoe_1b_7b", "train_4k", "single"),
        ("internvl2_76b", "train_4k", "single"),
    ]
    base = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"
    for a, s, m in cells:
        p = base / f"{a}__{s}__{m}.json"
        if not p.exists():
            continue
        r = analyze_cell(p)
        if r.get("status") != "OK":
            continue
        step = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        emit(f"roofline_{a}_{s}", step * 1e6,
             f"{r['bottleneck']}-bound;mfu={r['roofline_fraction_mfu']:.3f}")


def serve_bench(quick: bool = False,
                out_path: str = "BENCH_serve.json") -> None:
    """Closed-loop serving-front-end matrix (DESIGN.md Sec 12): per-op
    p50/p95/p99 tail latency and saturation throughput, pipelined
    coalescer vs synchronous per-request baseline, plus the 10k-deep
    burst drain.  Delegates to ``benchmarks.loadgen``; BENCH_serve.json."""
    from benchmarks import loadgen
    loadgen.bench_serve(quick=quick, out_path=out_path)


WAL_CFG = UruvConfig(leaf_cap=32, max_leaves=1 << 10, max_versions=1 << 16,
                     max_chain=64)
WAL_WIDTH = 1024
WAL_RESIDENT = 8192


def _du(path) -> int:
    return sum(f.stat().st_size for f in Path(path).rglob("*") if f.is_file())


def wal_bench(quick: bool = False, out_path: str = "BENCH_wal.json") -> None:
    """Durability costs (DESIGN.md Sec 14); BENCH_wal.json.

    Workload: a resident working set (prefilled + checkpointed), then the
    serving-table traffic pattern from the ``mixed`` bench — 90% SEARCH /
    5% INSERT / 5% DELETE over live keys — as the WAL tail.

    (a) *Group commit vs fsync-per-plan*: the same traffic through a
    durable client with ``group_commit=1`` (every confirmed plan is
    fsynced before its result is released) vs ``group_commit=16`` (one
    fsync amortizes a window of plans; the coalescer's ``flush`` closes
    it).  A volatile client runs alongside so the WAL overhead itself is
    visible.

    (b) *Delta vs full checkpoint* after a small dirty batch: full save of
    the resident store, ONE narrow update plan, then a delta save — bytes
    on disk and save latency.  GATED: the delta must be <= 25% of the full
    save's bytes (the version-tail fast path + per-leaf row diffs are the
    whole point of the delta chain).

    (c) *Recovery*: reopen the ``group_commit=1`` directory from (a) —
    checkpoint restore + WAL-tail replay at the recorded timestamps.  The
    restore cost is isolated by also recovering a copy of the directory
    taken before the tail was written, so the replay rate is
    (tail ops) / (total - restore).  GATED: >= 50k replayed ops/s on CPU.
    """
    import shutil
    import tempfile
    import time as _time

    rng = np.random.default_rng(17)
    n_traffic = 12 if quick else 24
    manual = LifecyclePolicy(auto_grow=True, auto_maintain=False)
    resident = np.arange(WAL_RESIDENT, dtype=np.int32)
    prefill = [OpBatch.updates(resident[i:i + WAL_WIDTH],
                               resident[i:i + WAL_WIDTH] % 997 + 1)
               for i in range(0, WAL_RESIDENT, WAL_WIDTH)]
    traffic = []
    for _ in range(n_traffic):
        r = rng.random(WAL_WIDTH)
        codes = np.where(r < 0.90, OP_SEARCH,
                         np.where(r < 0.95, OP_INSERT, OP_DELETE),
                         ).astype(np.int32)
        keys = rng.integers(0, WAL_RESIDENT, WAL_WIDTH).astype(np.int32)
        traffic.append(OpBatch(codes, keys, (keys % 997 + 1).astype(np.int32)))
    report = {}
    root = Path(tempfile.mkdtemp(prefix="uruv_wal_bench_"))
    try:
        # ---- (a) group-commit throughput vs fsync-per-plan --------------
        def ingest(tag, gc):
            db = Uruv(WAL_CFG, policy=manual,
                      **({} if gc is None else
                         {"durable_dir": str(root / tag), "group_commit": gc}))
            for p in prefill:
                db.apply(p)
            if gc is not None:
                db.checkpoint()              # prune prefill: WAL = tail only
            if tag == "gc1":                 # restore-only baseline for (c)
                shutil.copytree(root / tag, root / "restore_base")
            t0 = _time.perf_counter()
            for p in traffic:
                db.apply(p)
            if gc is not None:
                db.sync_durable()            # close the group-commit window
            sec = _time.perf_counter() - t0
            if gc is not None:
                db.durability.close()
            return sec, db

        ingest("warmup", None)               # compiles every pass shape
        v_sec, _ = ingest("volatile", None)
        s_sec, _ = ingest("gc1", 1)
        g_sec, _ = ingest("gc16", 16)
        for tag, sec in (("volatile", v_sec), ("fsync_per_plan", s_sec),
                         ("group_commit16", g_sec)):
            emit(f"wal_ingest_{tag}", sec / n_traffic * 1e6,
                 f"{n_traffic * WAL_WIDTH / sec / 1e6:.3f}Mops/s")
        emit("wal_group_commit_speedup", s_sec / g_sec,
             f"{s_sec / g_sec:.2f}x")
        report["group_commit"] = {
            "plans": n_traffic, "width": WAL_WIDTH,
            "volatile_us_per_plan": round(v_sec / n_traffic * 1e6, 1),
            "fsync_per_plan_us": round(s_sec / n_traffic * 1e6, 1),
            "group_commit16_us_per_plan": round(g_sec / n_traffic * 1e6, 1),
            "speedup_vs_fsync_per_plan": round(s_sec / g_sec, 2),
        }

        # ---- (b) delta vs full checkpoint bytes + latency ---------------
        db = Uruv(WAL_CFG, durable_dir=str(root / "delta"), policy=manual)
        for p in prefill:
            db.apply(p)
        t0 = _time.perf_counter()
        db.checkpoint()                      # first save is always full
        full_sec = _time.perf_counter() - t0
        full_step = db.durability.ckpt.latest_step()
        full_bytes = _du(root / "delta" / "ckpt" / f"step_{full_step:08d}")

        dirty = rng.choice(resident, 256, replace=False).astype(np.int32)
        db.apply(OpBatch.updates(dirty, dirty % 31 + 1))   # small dirty batch
        t0 = _time.perf_counter()
        db.checkpoint(delta=True)
        delta_sec = _time.perf_counter() - t0
        delta_step = db.durability.ckpt.latest_step()
        delta_bytes = _du(root / "delta" / "ckpt" / f"step_{delta_step:08d}")
        db.durability.close()
        frac = delta_bytes / full_bytes
        emit("wal_ckpt_full", full_sec * 1e6, f"{full_bytes}B")
        emit("wal_ckpt_delta", delta_sec * 1e6, f"{delta_bytes}B")
        emit("wal_ckpt_delta_fraction", frac * 100, f"{frac:.3f}of_full")
        assert frac <= 0.25, \
            f"delta checkpoint is {frac:.1%} of the full save (gate: <=25%)"
        report["checkpoint"] = {
            "full_bytes": full_bytes, "full_us": round(full_sec * 1e6, 1),
            "delta_bytes": delta_bytes, "delta_us": round(delta_sec * 1e6, 1),
            "delta_fraction_of_full": round(frac, 4),
        }

        # ---- (c) recovery: checkpoint restore + WAL-tail replay ----------
        t0 = _time.perf_counter()
        db_b = Uruv.recover(str(root / "restore_base"), policy=manual)
        base_sec = _time.perf_counter() - t0
        assert db_b.recovery.replayed_plans == 0, db_b.recovery
        db_b.durability.close()
        t0 = _time.perf_counter()
        db_r = Uruv.recover(str(root / "gc1"), policy=manual)
        total_sec = _time.perf_counter() - t0
        assert db_r.recovery.replayed_plans == n_traffic, db_r.recovery
        db_r.durability.close()
        ops = n_traffic * WAL_WIDTH
        replay_sec = max(total_sec - base_sec, 1e-9)
        ops_s = ops / replay_sec
        emit("wal_recovery_restore", base_sec * 1e6, "0replayed")
        emit("wal_recovery_total", total_sec * 1e6, f"{n_traffic}plans")
        emit("wal_recovery_replay", replay_sec * 1e6,
             f"{ops_s / 1e3:.1f}Kops/s")
        assert ops_s >= 50_000, \
            f"recovery replayed {ops_s:.0f} ops/s (gate: >=50k ops/s)"
        report["recovery"] = {
            "restore_us": round(base_sec * 1e6, 1),
            "total_us": round(total_sec * 1e6, 1),
            "replayed_plans": n_traffic,
            "replayed_ops": ops,
            "replay_ops_per_s": round(ops_s),
        }
    finally:
        shutil.rmtree(root, ignore_errors=True)
    Path(out_path).write_text(json.dumps(report, indent=2) + "\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="fig8|fig9|complexity|kernels|mixed|range|"
                         "lifecycle|index|serve|wal|roofline")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    sections = {
        "fig8": lambda: fig8(args.quick),
        "fig9": lambda: fig9(args.quick),
        "complexity": table_complexity,
        "kernels": lambda: kernels(args.quick),
        "mixed": lambda: mixed(args.quick),
        "range": lambda: range_bench(args.quick),
        "lifecycle": lambda: lifecycle_bench(args.quick),
        "index": lambda: index_bench(args.quick),
        "serve": lambda: serve_bench(args.quick),
        "wal": lambda: wal_bench(args.quick),
        "roofline": roofline_summary,
    }
    if args.only:
        sections[args.only]()
        return
    for fn in sections.values():
        fn()


if __name__ == "__main__":
    main()
