"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (derived = Mops/s for ADT
workloads; see each section).  Figures:

  * fig8 a–c   — dictionary workloads, Uruv vs the flat-chunk baseline
                 (the paper's LF-B+Tree/OpenBw-Tree role), sweeping the
                 announce width (the paper's thread-count axis).
  * fig9 a–f   — range-query mixes, Uruv MVCC snapshot scans vs
                 validate-retry multi-scan (the paper's VCAS-BST role).
  * table_complexity — measured wait-free bound: passes per op vs
                 conflict concentration (the paper's m = f(I_C) bound).
  * kernels    — Uruv hot-path kernels, XLA path (CPU relative numbers).

Run: PYTHONPATH=src python -m benchmarks.run [--quick]
"""

from __future__ import annotations

import argparse
import sys

import numpy as np
import jax.numpy as jnp

from benchmarks import workloads as W
from repro.core import batch as B
from repro.core import store as S

WIDTHS = [64, 256, 1024, 4096]


def emit(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.2f},{derived}", flush=True)


def fig8(quick: bool = False) -> None:
    rng = np.random.default_rng(0)
    uruv = W.prefill_uruv(rng)
    flat = W.prefill_flat(rng)
    widths = WIDTHS[:2] if quick else WIDTHS
    for name, w in W.FIG8.items():
        for width in widths:
            uruv, sec = W.run_uruv(uruv, rng, w, width)
            emit(f"{name}_uruv_w{width}", sec * 1e6,
                 f"{width/sec/1e6:.3f}Mops/s")
            flat, fsec = W.run_flat(flat, rng, w, width)
            emit(f"{name}_flatbase_w{width}", fsec * 1e6,
                 f"{width/fsec/1e6:.3f}Mops/s")


def fig9(quick: bool = False) -> None:
    rng = np.random.default_rng(1)
    uruv = W.prefill_uruv(rng)
    flat = W.prefill_flat(rng)
    widths = [1024] if quick else [1024, 4096]
    figs = dict(list(W.FIG9.items())[:2]) if quick else W.FIG9
    for name, w in figs.items():
        for width in widths:
            uruv, sec = W.run_uruv(uruv, rng, w, width)
            emit(f"{name}_uruv_w{width}", sec * 1e6,
                 f"{width/sec/1e6:.3f}Mops/s")
            flat, fsec = W.run_flat(flat, rng, w, width)
            emit(f"{name}_validate_retry_w{width}", fsec * 1e6,
                 f"{width/fsec/1e6:.3f}Mops/s")


def table_complexity() -> None:
    """Wait-free bound: slow-path rounds vs conflict concentration.

    The paper bounds restarts by m = min(f + s*t, I_C) (interval
    contention).  The batch analogue: a prefilled store receives 1024 NEW
    keys concentrated in a span of the key space — the narrower the span,
    the more structural inserts collide on the same leaves and the more
    bounded help-rounds the combining layer runs.  Wide spans take the
    fast path (1 round)."""
    rng = np.random.default_rng(2)
    base_keys = rng.choice(1_000_000, 100_000, replace=False) \
        .astype(np.int32) * 2           # even keys prefilled
    for span in (1_000_000, 65_536, 8_192, 2_048):
        st = S.create(S.UruvConfig(leaf_cap=16, max_leaves=1 << 15,
                                   max_versions=1 << 19))
        for i in range(0, 100_000, 4096):
            st, _ = B.apply_updates(st, base_keys[i:i+4096],
                                    base_keys[i:i+4096])
        new = (rng.choice(span // 2, 1024, replace=False)
               .astype(np.int32) * 2 + 1)      # odd keys: all new
        calls = {"n": 0}
        orig = S.bulk_update

        def counting(st_, k, v):
            calls["n"] += 1
            return orig(st_, k, v)

        S.bulk_update = counting
        try:
            st, _ = B.apply_updates(st, new, new)
        finally:
            S.bulk_update = orig
        emit(f"complexity_span{span}_passes", float(calls["n"]),
             f"{calls['n']}rounds")


def kernels(quick: bool = False) -> None:
    rng = np.random.default_rng(3)
    st = W.prefill_uruv(rng)
    q = rng.integers(0, W.UNIVERSE, 4096).astype(np.int32)
    sec = W.timed(lambda: S.bulk_lookup(
        st, jnp.asarray(q),
        jnp.asarray(int(st.ts), jnp.int32)).block_until_ready())
    emit("kernel_locate_resolve_4096", sec * 1e6,
         f"{4096/sec/1e6:.2f}Mlookups/s")
    ts = int(st.ts)
    sec = W.timed(lambda: S.range_query(
        st, 100_000, 101_000, ts, max_scan_leaves=64,
        max_results=2048)[0].block_until_ready())
    emit("kernel_range1k_snapshot", sec * 1e6, "1scan")


def roofline_summary() -> None:
    """Dry-run roofline: dominant term for the hillclimbed cells (full
    table in EXPERIMENTS.md; reads experiments/dryrun artifacts)."""
    from pathlib import Path
    from repro.launch.roofline import analyze_cell

    cells = [
        ("llama3_2_1b", "decode_32k", "single"),
        ("olmoe_1b_7b", "train_4k", "single"),
        ("internvl2_76b", "train_4k", "single"),
    ]
    base = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"
    for a, s, m in cells:
        p = base / f"{a}__{s}__{m}.json"
        if not p.exists():
            continue
        r = analyze_cell(p)
        if r.get("status") != "OK":
            continue
        step = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        emit(f"roofline_{a}_{s}", step * 1e6,
             f"{r['bottleneck']}-bound;mfu={r['roofline_fraction_mfu']:.3f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="fig8|fig9|complexity|kernels|roofline")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    sections = {
        "fig8": lambda: fig8(args.quick),
        "fig9": lambda: fig9(args.quick),
        "complexity": table_complexity,
        "kernels": lambda: kernels(args.quick),
        "roofline": roofline_summary,
    }
    if args.only:
        sections[args.only]()
        return
    for fn in sections.values():
        fn()


if __name__ == "__main__":
    main()
