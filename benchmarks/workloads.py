"""Paper workload generators + timing helpers (Sec 6 benchmark protocol).

The paper sweeps thread count on a 40-core Power9; the TPU-native analogue
of "concurrent threads" is the announce-array width (ops per wait-free
batch pass) — DESIGN.md Sec 2.  We report throughput (Mops/s) vs width.

Protocol mirrors the paper: prefill with uniform keys from a universe,
uniform op mix, average of the last runs after warmup.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.api import KEY_MAX, TOMBSTONE, OpBatch, Uruv, UruvConfig
from repro.core import baseline as BL


@dataclasses.dataclass
class Workload:
    read: float
    update: float          # split evenly insert/delete
    range_q: float = 0.0
    range_size: int = 1000


# paper figures
FIG8 = {
    "fig8a_read100": Workload(1.0, 0.0),
    "fig8b_read95_upd5": Workload(0.95, 0.05),
    "fig8c_read50_upd50": Workload(0.5, 0.5),
}
FIG9 = {
    "fig9a_r94_u5_rq1": Workload(0.94, 0.05, 0.01),
    "fig9b_r90_u5_rq5": Workload(0.90, 0.05, 0.05),
    "fig9c_r85_u5_rq10": Workload(0.85, 0.05, 0.10),
    "fig9d_r49_u50_rq1": Workload(0.49, 0.50, 0.01),
    "fig9e_r45_u50_rq5": Workload(0.45, 0.50, 0.05),
    "fig9f_r40_u50_rq10": Workload(0.40, 0.50, 0.10),
}

UNIVERSE = 2_000_000
PREFILL = 200_000
STORE_CFG = UruvConfig(leaf_cap=64, max_leaves=1 << 14,
                       max_versions=1 << 21, max_chain=64)


def timed(fn: Callable[[], None], repeats: int = 5, warmup: int = 2) -> float:
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return float(np.mean(ts[: max(1, len(ts) - 1)]))   # drop worst (paper: outliers)


def prefill_uruv(rng) -> Uruv:
    db = Uruv(STORE_CFG)
    keys = rng.choice(UNIVERSE, PREFILL, replace=False).astype(np.int32)
    for i in range(0, PREFILL, 4096):
        db.apply(OpBatch.updates(keys[i:i+4096],
                                 keys[i:i+4096] % 1000 + 1))
    return db


def prefill_flat(rng) -> BL.FlatStore:
    st = BL.create(1 << 19)
    keys = rng.choice(UNIVERSE, PREFILL, replace=False).astype(np.int32)
    st = BL.bulk_update(st, jnp.asarray(keys),
                        jnp.asarray(keys % 1000 + 1))
    return st


def op_batch(rng, w: Workload, width: int):
    """(lookup_keys, update_keys, update_vals, n_rq) for one announce pass."""
    r = rng.random(width)
    keys = rng.integers(0, UNIVERSE, width).astype(np.int32)
    is_read = r < w.read
    is_upd = (r >= w.read) & (r < w.read + w.update)
    lookup = np.where(is_read, keys, KEY_MAX).astype(np.int32)
    upd_k = np.where(is_upd, keys, KEY_MAX).astype(np.int32)
    dels = rng.random(width) < 0.5
    upd_v = np.where(dels, TOMBSTONE, keys % 1000 + 1).astype(np.int32)
    n_rq = int(np.round(width * w.range_q))
    return lookup, upd_k, upd_v, n_rq


def run_uruv(db: Uruv, rng, w: Workload, width: int,
             iters: int = 4) -> Tuple[Uruv, float]:
    """Returns (client, seconds per `width` ops)."""
    batches = [op_batch(rng, w, width) for _ in range(iters)]
    rq_starts = rng.integers(0, UNIVERSE - w.range_size,
                             max(1, iters * 8)).astype(np.int32)

    def body():
        k = 0
        for lookup, upd_k, upd_v, n_rq in batches:
            db.apply(OpBatch.updates(upd_k, upd_v))
            ts = db.ts
            db.lookup(lookup, ts)          # np round-trip == block
            for _ in range(n_rq):
                lo = int(rq_starts[k % len(rq_starts)]); k += 1
                db.scan_page(lo, lo + w.range_size, ts,
                             max_scan_leaves=64,
                             max_results=2048).keys.block_until_ready()

    sec = timed(body)
    return db, sec / iters


def run_flat(store: BL.FlatStore, rng, w: Workload, width: int,
             iters: int = 4) -> Tuple[BL.FlatStore, float]:
    batches = [op_batch(rng, w, width) for _ in range(iters)]
    rq_starts = rng.integers(0, UNIVERSE - w.range_size,
                             max(1, iters * 8)).astype(np.int32)
    holder = {"st": store}

    def body():
        st = holder["st"]
        k = 0
        for lookup, upd_k, upd_v, n_rq in batches:
            st = BL.bulk_update(st, jnp.asarray(upd_k), jnp.asarray(upd_v))
            BL.bulk_lookup(st, jnp.asarray(lookup)).block_until_ready()
            for _ in range(n_rq):
                lo = int(rq_starts[k % len(rq_starts)]); k += 1
                # validate-retry: the concurrent updater (this loop) forces
                # a second scan at minimum (Brown-Avni multi-scan)
                snap = {"n": 0}

                def ref():
                    snap["n"] += 1
                    return st

                BL.range_query_validated(ref, lo, lo + w.range_size,
                                         max_results=2048)
        holder["st"] = st

    sec = timed(body)
    return holder["st"], sec / iters
