"""Closed-loop load generator + tail-latency harness for the serving
front end (``repro.serve.coalescer``); writes BENCH_serve.json.

Workload model follows the MTASet evaluation matrix (arXiv:2507.20041):
mixed CRUD/range MIXES crossed with key-popularity DISTRIBUTIONS
(uniform and zipfian — the skewed case is where coalescing policy earns
its keep) and ARRIVAL shapes (steady closed loop vs bursty waves).  Each
simulated client keeps exactly one small request outstanding (closed
loop): it submits, waits for its :class:`OpFuture`, records the
submit→complete wall time, and immediately submits the next — so the
measured throughput is the saturation point of the admission pipeline,
and the recorded latencies are true per-op queueing + batching +
execution times (reported as p50/p95/p99 microseconds per op).

Every cell also replays the EXACT same request stream through a
synchronous per-request ``Uruv.apply`` baseline on an identical store —
the speedup column is measured in the same run, same machine, same
store state.  The quick cells gate CI: the pipelined front end must
reach >= 2x the synchronous saturation throughput.

A separate burst phase floods the admission queue 10_000 requests deep
before the first drain — the regression harness for the former O(n)
``list.pop(0)`` admission queue (quadratic drain; now a deque).

Run: PYTHONPATH=src python -m benchmarks.run --only serve [--quick]
"""

from __future__ import annotations

import json
import time
from typing import Dict, List, Tuple

import numpy as np

from repro.api import OpBatch, Uruv, UruvConfig
from repro.serve.coalescer import AdmissionPolicy, Coalescer

UNIVERSE = 1 << 20          # key domain (well inside [1, KEY_MAX - 2])
RESIDENT = 50_000           # prefilled live keys
ZIPF_S = 1.1
ZIPF_RANKS = 4096

# MTASet-style op mixes: (insert, delete, search, range) fractions
MIXES: Dict[str, Tuple[float, float, float, float]] = {
    "update_heavy": (0.35, 0.15, 0.50, 0.00),
    "read_heavy":   (0.05, 0.05, 0.90, 0.00),
    "range_mix":    (0.10, 0.05, 0.75, 0.10),
}

# cell = (distribution, mix, arrival); the first two are the CI gate
CELLS = [
    ("zipf", "update_heavy", "bursty"),
    ("uniform", "read_heavy", "steady"),
    ("zipf", "read_heavy", "steady"),
    ("uniform", "update_heavy", "bursty"),
    ("zipf", "range_mix", "steady"),
]


def emit(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.2f},{derived}", flush=True)


# ------------------------------------------------------------- samplers
def make_sampler(rng: np.random.Generator, dist: str):
    """Key sampler: uniform over the domain, or zipfian over a random
    hot set (rank r drawn with probability ~ r**-s via the generator's
    alias table — no sorted-array descent here, the index layering gate
    forbids it outside the core)."""
    if dist == "uniform":
        return lambda n: rng.integers(1, UNIVERSE, n).astype(np.int32)
    ranks = np.arange(1, ZIPF_RANKS + 1, dtype=np.float64)
    p = ranks ** -ZIPF_S
    p /= p.sum()
    hot = rng.permutation(UNIVERSE - 1)[:ZIPF_RANKS].astype(np.int32) + 1
    return lambda n: hot[rng.choice(ZIPF_RANKS, size=n, p=p)]


def gen_request(rng: np.random.Generator, mix: str, sample) -> OpBatch:
    """One client request: 1-4 ops drawn from the mix."""
    n = int(rng.integers(1, 5))
    fi, fd, fs, fr = MIXES[mix]
    r = rng.random(n)
    keys = sample(n)
    parts = []
    for i in range(n):
        k = int(keys[i])
        if r[i] < fi:
            parts.append(OpBatch.inserts([k], [k % 1000 + 1]))
        elif r[i] < fi + fd:
            parts.append(OpBatch.deletes([k]))
        elif r[i] < fi + fd + fs:
            parts.append(OpBatch.searches([k]))
        else:
            parts.append(OpBatch.ranges([k], [min(k + 64, UNIVERSE)]))
    return OpBatch.concat(*parts)


def warm_shapes(db: Uruv, max_w: int = 1024) -> None:
    """Compile every pow2 plan-shape bucket on a scratch store copy, off
    the clock.  CPU jit compile is seconds per shape; without this the
    first cell's tail is compile time, not admission-pipeline behavior
    (the jit cache is keyed on shapes, so the scratch copy warms it for
    every same-shaped store)."""
    scratch = Uruv.from_store(db.store)
    w = 1
    while w <= max_w:
        plan = OpBatch.searches(np.arange(1, w + 1, dtype=np.int32))
        scratch.apply(plan)
        scratch.confirm(scratch.apply_nowait(plan))
        w *= 2


# -------------------------------------------------------------- prefill
def prefill_store(rng: np.random.Generator) -> Uruv:
    db = Uruv(UruvConfig(leaf_cap=64, max_leaves=1 << 12,
                         max_versions=1 << 18, max_chain=64))
    keys = rng.choice(UNIVERSE - 1, RESIDENT, replace=False) \
        .astype(np.int32) + 1
    for i in range(0, RESIDENT, 4096):
        seg = keys[i:i + 4096]
        db.apply(OpBatch.inserts(seg, seg % 1000 + 1))
    return db


# ---------------------------------------------------------- closed loop
def run_pipelined(db: Uruv, requests: List[OpBatch], n_clients: int,
                  bursty: bool) -> Tuple[np.ndarray, float]:
    """Drive the coalescer closed-loop: each of ``n_clients`` keeps one
    request outstanding.  Returns (per-op latencies [s], elapsed [s])."""
    c = Coalescer(db, AdmissionPolicy())
    lat: List[float] = []
    pending: List = []
    next_req = 0
    idle = n_clients
    burst = max(1, n_clients // 2)
    t0 = time.monotonic()
    while next_req < len(requests) or pending:
        can_submit = next_req < len(requests) and idle > 0
        if can_submit and (not bursty or idle >= burst or not pending):
            while idle and next_req < len(requests):
                pending.append(c.submit(requests[next_req]))
                next_req += 1
                idle -= 1
        if not c.pump():
            c.pump(force=True)
        still = []
        for f in pending:
            if f.done:
                lat.extend([f.done_t - f.submit_t] * f.n_ops)
                idle += 1
            else:
                still.append(f)
        pending = still
    c.flush()
    return np.asarray(lat), time.monotonic() - t0


def run_sync(db: Uruv, requests: List[OpBatch]) -> Tuple[np.ndarray, float]:
    """The per-request synchronous baseline: one ``Uruv.apply`` (one
    host-synced device pass, at least) per client request."""
    lat: List[float] = []
    t0 = time.monotonic()
    for req in requests:
        s = time.monotonic()
        db.apply(req, pad_to_pow2=True)
        lat.extend([time.monotonic() - s] * len(req))
    return np.asarray(lat), time.monotonic() - t0


def run_burst(db: Uruv, depth: int) -> Tuple[float, Dict[str, int]]:
    """Flood the admission queue ``depth`` requests deep, then drain —
    the O(n)-queue regression harness (list.pop(0) made this quadratic)."""
    c = Coalescer(db, AdmissionPolicy(max_width=1024))
    rng = np.random.default_rng(11)
    keys = rng.choice(UNIVERSE - 1, depth, replace=False) \
        .astype(np.int32) + 1
    t0 = time.monotonic()
    futs = [c.submit(OpBatch.inserts([int(k)], [1])) for k in keys]
    c.flush()
    assert all(f.done for f in futs)
    elapsed = time.monotonic() - t0
    assert c.stats["max_queue_depth"] == depth, c.stats
    return elapsed, dict(c.stats)


# ------------------------------------------------------------------ main
def bench_serve(quick: bool = False,
                out_path: str = "BENCH_serve.json") -> None:
    """Tail-latency + saturation-throughput matrix; writes BENCH_serve.json.

    Gates (quick cells): the pipelined front end must sustain >= 2x the
    synchronous per-request baseline's saturation throughput on both the
    zipfian and the uniform CRUD cells, measured in the same run.
    """
    n_cells = 2 if quick else len(CELLS)
    target_ops = 1500 if quick else 6000
    n_clients = 32
    report: Dict[str, Dict] = {"cells": {}, "quick": quick,
                               "n_clients": n_clients,
                               "target_ops_per_cell": target_ops}
    gated: List[Tuple[str, float]] = []
    seed_db = prefill_store(np.random.default_rng(7))
    warm_shapes(seed_db)
    for cell_i, (dist, mix, arrival) in enumerate(CELLS[:n_cells]):
        name = f"{dist}_{mix}"
        rng = np.random.default_rng([13, cell_i])
        sample = make_sampler(rng, dist)
        requests, ops = [], 0
        while ops < target_ops:
            req = gen_request(rng, mix, sample)
            requests.append(req)
            ops += len(req)

        db_p = Uruv.from_store(seed_db.store)
        lat_p, el_p = run_pipelined(db_p, requests, n_clients,
                                    bursty=(arrival == "bursty"))
        db_s = Uruv.from_store(seed_db.store)
        lat_s, el_s = run_sync(db_s, requests)

        thr_p = len(lat_p) / el_p
        thr_s = len(lat_s) / el_s
        speedup = thr_p / thr_s
        p50, p95, p99 = np.percentile(lat_p * 1e6, [50, 95, 99])
        s50, s95, s99 = np.percentile(lat_s * 1e6, [50, 95, 99])
        report["cells"][name] = {
            "arrival": arrival, "ops": int(len(lat_p)),
            "pipelined": {"p50_us": round(float(p50), 1),
                          "p95_us": round(float(p95), 1),
                          "p99_us": round(float(p99), 1),
                          "throughput_ops_s": round(thr_p, 1)},
            "sync_baseline": {"p50_us": round(float(s50), 1),
                              "p95_us": round(float(s95), 1),
                              "p99_us": round(float(s99), 1),
                              "throughput_ops_s": round(thr_s, 1)},
            "throughput_speedup": round(speedup, 2),
        }
        emit(f"serve_{name}_p99", p99, f"{thr_p/1e3:.1f}Kops/s")
        emit(f"serve_{name}_sync_p99", s99, f"{thr_s/1e3:.1f}Kops/s")
        emit(f"serve_{name}_speedup", speedup, f"{speedup:.2f}x")
        if mix != "range_mix":
            gated.append((name, speedup))

    depth = 10_000
    db_b = Uruv.from_store(seed_db.store)
    burst_s, burst_stats = run_burst(db_b, depth)
    report["burst"] = {"depth": depth, "drain_s": round(burst_s, 3),
                      "ops_s": round(depth / burst_s, 1),
                      "plans": burst_stats["plans"]}
    emit("serve_burst_10k_drain", burst_s * 1e6,
         f"{depth/burst_s/1e3:.1f}Kops/s")

    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")

    for name, speedup in gated:
        assert speedup >= 2.0, (
            f"pipelined front end only {speedup:.2f}x sync baseline on "
            f"{name} (gate: >= 2x saturation throughput)")


if __name__ == "__main__":
    bench_serve(quick=True)
