"""Run every example under the Pallas interpret backend, failing on any
DeprecationWarning raised from inside ``src/repro`` — the internals must be
fully migrated onto ``repro.api`` (deprecated shims are for external
callers only).

  PYTHONPATH=src python scripts/run_examples.py           # all examples
  PYTHONPATH=src python scripts/run_examples.py quickstart streaming

``URUV_BACKEND=pallas_interpret`` routes every store device pass through
the Pallas kernels in interpret mode, so the examples double as end-to-end
kernel-contract checks off-TPU (the model/training code is backend-
independent and unaffected).
"""

import os
import runpy
import sys
import tempfile
import time
import warnings
from pathlib import Path

os.environ.setdefault("URUV_BACKEND", "pallas_interpret")

# A DeprecationWarning attributed to a repro.* module (the shims warn with
# stacklevel=2, so attribution lands on the CALLER) means an internal code
# path still uses a deprecated entry point -> hard failure.  Examples and
# third-party warnings are unaffected.
warnings.filterwarnings(
    "error", category=DeprecationWarning, module=r"repro($|\..*)"
)

ROOT = Path(__file__).resolve().parents[1]
# train_lm gets a FRESH checkpoint dir: a stale one from a previous run
# would make the loop restore-and-skip the whole demo (hermetic gate)
_CKPT = tempfile.mkdtemp(prefix="repro_examples_ckpt_")
EXAMPLES = [
    ("quickstart", "examples/quickstart.py", []),
    ("streaming", "examples/streaming_analytics.py", []),
    ("train_lm", "examples/train_lm.py", ["--demo", "--ckpt-dir", _CKPT]),
    ("serve_lm", "examples/serve_lm.py", []),
]


def main() -> None:
    only = set(sys.argv[1:])
    unknown = only - {name for name, _, _ in EXAMPLES}
    if unknown:
        names = ", ".join(name for name, _, _ in EXAMPLES)
        print(f"unknown example(s): {sorted(unknown)}; choose from: {names}")
        sys.exit(2)
    failures = []
    for name, rel, argv in EXAMPLES:
        if only and name not in only:
            continue
        path = ROOT / rel
        print(f"== example: {rel} {' '.join(argv)} "
              f"(URUV_BACKEND={os.environ['URUV_BACKEND']}) ==", flush=True)
        sys.argv = [str(path)] + argv
        t0 = time.time()
        try:
            runpy.run_path(str(path), run_name="__main__")
        except Exception as e:                      # noqa: BLE001 - CI gate
            failures.append((rel, repr(e)))
            print(f"!! {rel} FAILED: {e!r}", flush=True)
        else:
            print(f"== ok: {rel} ({time.time() - t0:.1f}s) ==", flush=True)
    if failures:
        for rel, err in failures:
            print(f"FAILED {rel}: {err}")
        sys.exit(1)
    print("all examples ok")


if __name__ == "__main__":
    main()
