"""Docs-that-run gate: extract fenced ``python`` blocks from README.md and
DESIGN.md and execute them under ``URUV_BACKEND=pallas_interpret``, so the
documented quickstarts and API snippets can never rot — a doc block that
stops working fails `scripts/check.sh` exactly like a test.

  PYTHONPATH=src python scripts/check_docs.py            # all docs
  PYTHONPATH=src python scripts/check_docs.py README.md  # one file

Rules:
  * only fences whose info string is exactly ``python`` run (``python
    no-run`` or any other tag is skipped — for illustrative fragments);
  * each block runs in a FRESH namespace (blocks must be self-contained,
    like the docs claim they are);
  * the interpret backend routes every store device pass through the
    Pallas kernels, so doc snippets double as kernel-contract checks
    off-TPU.
"""

import os
import re
import sys
import time
import traceback
from pathlib import Path

os.environ.setdefault("URUV_BACKEND", "pallas_interpret")

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

DOCS = ["README.md", "DESIGN.md"]

FENCE = re.compile(
    r"^```([^\n`]*)\n(.*?)^```\s*$", re.MULTILINE | re.DOTALL
)


def blocks(path: Path):
    """Yield (line_number, code) for every runnable ``python`` fence."""
    text = path.read_text()
    for m in FENCE.finditer(text):
        info = m.group(1).strip()
        if info != "python":
            continue
        line = text[: m.start()].count("\n") + 2   # first code line
        yield line, m.group(2)


def main() -> int:
    targets = sys.argv[1:] or DOCS
    total = failed = 0
    for name in targets:
        path = ROOT / name
        if not path.exists():
            print(f"SKIP {name} (missing)")
            continue
        for line, code in blocks(path):
            total += 1
            tag = f"{name}:{line}"
            t0 = time.perf_counter()
            try:
                exec(compile(code, tag, "exec"), {"__name__": "__docs__"})
            except Exception:
                failed += 1
                print(f"FAIL {tag}")
                traceback.print_exc()
                continue
            print(f"ok   {tag}  ({time.perf_counter() - t0:.1f}s)")
    print(f"{total - failed}/{total} doc blocks passed")
    if total == 0:
        print("ERROR: no runnable ``python`` blocks found — docs gate "
              "would be vacuous")
        return 1
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
