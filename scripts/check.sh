#!/usr/bin/env bash
# Tier-1 gate + perf trajectory.  Run from the repo root:  bash scripts/check.sh
# (or `make check`).  Writes BENCH_mixed.json so the fused-pass speedup
# accumulates across PRs.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== kernel microbench (quick) =="
python -m benchmarks.run --quick --only kernels

echo "== fused mixed-op pass vs two-pass (quick; writes BENCH_mixed.json) =="
python -m benchmarks.run --quick --only mixed

echo "== BENCH_mixed.json =="
cat BENCH_mixed.json
