#!/usr/bin/env bash
# Tier-1 gate + perf trajectory.  Run from the repo root:  bash scripts/check.sh
# (or `make check`).  Writes BENCH_mixed.json + BENCH_range.json so the
# fused-pass speedups accumulate across PRs.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== api layering gate (non-core modules go through repro.api only) =="
# import statements only (prose mentions of repro.core.* in docstrings are
# fine): `from repro.core import store`, `from repro.core.store import ...`,
# `import repro.core.store`
if grep -RnE "^[[:space:]]*(from repro\.core import [^#]*\b(store|batch|sharded|lifecycle)\b|from repro\.core\.(store|batch|sharded|lifecycle)\b|import repro\.core\.(store|batch|sharded|lifecycle)\b)" \
     --include="*.py" --exclude-dir=core --exclude-dir=api \
     src/repro benchmarks examples scripts; then
  echo "ERROR: module bypasses repro.api (import core internals directly)"
  exit 1
fi
echo "ok"

echo "== index layering gate (descent internals live in core/index.py + core/backend.py) =="
# The flat-directory era is over: no module may touch dir_keys/dir_leaf or
# run a searchsorted-style descent outside the index/backend pair (plus
# their Pallas kernel twins under kernels/uruv_search and the deliberately
# flat comparison baseline core/baseline.py).  Ordinal/rank access goes
# through repro.core.index helpers; sanctioned non-descent searchsorted
# uses go through index.rank().
if grep -RnE "dir_keys|dir_leaf|searchsorted" --include="*.py" \
     src/repro benchmarks examples scripts \
   | grep -vE "src/repro/core/(index|backend|baseline)\.py|src/repro/kernels/uruv_search/"; then
  echo "ERROR: flat-directory/descent access outside core/index.py + core/backend.py"
  exit 1
fi
echo "ok"

echo "== tier-1 tests (slow-marked growth batteries excluded via pytest.ini) =="
# The full suite (pytest -x -q) includes the range/snapshot battery
# (tests/test_range_property.py), the kernel + sharded range parity tests
# (tests/test_kernels.py, tests/test_sharding_dist.py) and the public-API
# surface battery (tests/test_api.py).
python -m pytest -x -q

echo "== kernel microbench (quick) =="
python -m benchmarks.run --quick --only kernels

echo "== fused mixed-op pass vs two-pass (quick; writes BENCH_mixed.json) =="
python -m benchmarks.run --quick --only mixed

echo "== batched bulk_range vs host-paged loop (quick; writes BENCH_range.json) =="
python -m benchmarks.run --quick --only range

echo "== lifecycle: maintain vs compact + grow amortization (quick; writes BENCH_lifecycle.json) =="
python -m benchmarks.run --quick --only lifecycle

echo "== index: delta maintenance vs flat full-rebuild + locate depth sweep (quick; writes BENCH_index.json) =="
python -m benchmarks.run --quick --only index

echo "== serve: pipelined front end tail latency vs sync baseline (quick; gates >=2x; writes BENCH_serve.json) =="
python -m benchmarks.run --quick --only serve

echo "== BENCH_serve.json =="
cat BENCH_serve.json

echo "== BENCH_index.json =="
cat BENCH_index.json

echo "== BENCH_mixed.json =="
cat BENCH_mixed.json

echo "== BENCH_range.json =="
cat BENCH_range.json

echo "== BENCH_lifecycle.json =="
cat BENCH_lifecycle.json

echo "== examples under pallas_interpret (DeprecationWarning from repro = fail) =="
python scripts/run_examples.py

echo "== docs-that-run: README/DESIGN fenced python blocks under pallas_interpret =="
python scripts/check_docs.py
