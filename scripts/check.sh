#!/usr/bin/env bash
# Tier-1 gate + perf trajectory.  Run from the repo root:  bash scripts/check.sh
# (or `make check`).  Writes BENCH_mixed.json + BENCH_range.json so the
# fused-pass speedups accumulate across PRs.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
# The full suite (pytest -x -q) includes the range/snapshot battery
# (tests/test_range_property.py) and the kernel + sharded range parity
# tests (tests/test_kernels.py, tests/test_sharding_dist.py).
python -m pytest -x -q

echo "== kernel microbench (quick) =="
python -m benchmarks.run --quick --only kernels

echo "== fused mixed-op pass vs two-pass (quick; writes BENCH_mixed.json) =="
python -m benchmarks.run --quick --only mixed

echo "== batched bulk_range vs host-paged loop (quick; writes BENCH_range.json) =="
python -m benchmarks.run --quick --only range

echo "== BENCH_mixed.json =="
cat BENCH_mixed.json

echo "== BENCH_range.json =="
cat BENCH_range.json
