#!/usr/bin/env bash
# Tier-1 gate + perf trajectory.  Run from the repo root:  bash scripts/check.sh
# (or `make check`).  Writes BENCH_mixed.json + BENCH_range.json so the
# fused-pass speedups accumulate across PRs.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== uruvlint (static analysis: layering, device-pass purity, donation"
echo "   safety, determinism, kernel parity/VMEM, sentinel literals) =="
# Replaces the former api/index grep gates with AST analysis (resolves
# relative imports, never trips on docstring prose) and adds the purity /
# donation / determinism / kernel / sentinel rules on top.  Rule catalog +
# suppression syntax: DESIGN.md Sec 13.  `make lint` runs the same stage.
python -m repro.analysis src/repro benchmarks examples scripts
echo "ok"

echo "== tier-1 tests (slow-marked growth batteries excluded via pytest.ini) =="
# The full suite (pytest -x -q) includes the range/snapshot battery
# (tests/test_range_property.py), the kernel + sharded range parity tests
# (tests/test_kernels.py, tests/test_sharding_dist.py) and the public-API
# surface battery (tests/test_api.py).
python -m pytest -x -q

echo "== kernel microbench (quick) =="
python -m benchmarks.run --quick --only kernels

echo "== fused mixed-op pass vs two-pass (quick; writes BENCH_mixed.json) =="
python -m benchmarks.run --quick --only mixed

echo "== batched bulk_range vs host-paged loop (quick; writes BENCH_range.json) =="
python -m benchmarks.run --quick --only range

echo "== lifecycle: maintain vs compact + grow amortization (quick; writes BENCH_lifecycle.json) =="
python -m benchmarks.run --quick --only lifecycle

echo "== index: delta maintenance vs flat full-rebuild + locate depth sweep (quick; writes BENCH_index.json) =="
python -m benchmarks.run --quick --only index

echo "== serve: pipelined front end tail latency vs sync baseline (quick; gates >=2x; writes BENCH_serve.json) =="
python -m benchmarks.run --quick --only serve

echo "== wal: group-commit vs fsync-per-plan, delta vs full checkpoint,"
echo "   recovery replay (quick; gates delta<=25% of full + replay>=50kops/s;"
echo "   writes BENCH_wal.json) =="
python -m benchmarks.run --quick --only wal

echo "== BENCH_wal.json =="
cat BENCH_wal.json

echo "== BENCH_serve.json =="
cat BENCH_serve.json

echo "== BENCH_index.json =="
cat BENCH_index.json

echo "== BENCH_mixed.json =="
cat BENCH_mixed.json

echo "== BENCH_range.json =="
cat BENCH_range.json

echo "== BENCH_lifecycle.json =="
cat BENCH_lifecycle.json

echo "== examples under pallas_interpret (DeprecationWarning from repro = fail) =="
python scripts/run_examples.py

echo "== docs-that-run: README/DESIGN fenced python blocks under pallas_interpret =="
python scripts/check_docs.py
