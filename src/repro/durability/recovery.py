"""The durability sidecar + crash recovery (DESIGN.md Sec 14).

`Durability` owns one durable directory:

    <dir>/uruv.json     construction config (so recovery of an empty
                        store needs no checkpoint)
    <dir>/wal/          the announce WAL (repro.durability.wal)
    <dir>/ckpt/         checkpoints (repro.checkpoint.manager — full
                        saves + delta chains)

The executors log every committed plan through :meth:`Durability.log_plan`
(append + fsync-bounded group commit) BEFORE its result reaches the
caller; :func:`recover` restores the latest complete checkpoint (walking
a delta chain if that is what is on disk) and replays the WAL tail — each
record re-applied at its recorded ``base_ts``, so every version timestamp
comes out bit-identical to the uninterrupted run (the same ``op_ts``
plumbing that makes sharded == local).

Replay rules (deterministic recover-or-reject):

  * ``next_ts <= clock``  — already inside the checkpoint (or a duplicate
    segment replay): skip;
  * ``base_ts == clock``  — apply;
  * anything else         — a gap or a straddling record: the log and the
    checkpoint disagree about history — :class:`WalReplayError`, never a
    silently diverging store.

Read ops (SEARCH / RANGE) replay as NOPs: they wrote nothing, and a NOP
occupies the identical announce slot, so the clock — and therefore every
later version timestamp — advances exactly as it originally did, without
re-running pagination loops.

Everything on this path is deterministic by construction: no wall clock,
no host RNG (the ``determinism`` uruvlint rule gates the whole package).
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import List, Optional

import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core.ref import KEY_MAX, OP_NOP, OP_RANGE, OP_SEARCH
from repro.durability.wal import (
    DEFAULT_SEGMENT_BYTES, Wal, WalRecord, WalReport,
)

CONFIG_FILE = "uruv.json"


class WalReplayError(RuntimeError):
    """The WAL and the checkpoint disagree about history."""


@dataclasses.dataclass(frozen=True)
class RecoveryInfo:
    """What :func:`recover` did — surfaced as ``Uruv.recovery``."""

    wal: WalReport                    # incl. exactly what open() truncated
    checkpoint_step: Optional[int]    # None = recovered from uruv.json only
    replayed_plans: int
    recovered_ts: int


class Durability:
    """WAL + checkpoint manager + config persistence for one client.

    ``group_commit`` bounds the fsync window: 1 (default) fsyncs every
    logged plan before its result is released; k > 1 lets up to k - 1
    confirmed plans await the next fsync (close the window with
    :meth:`sync` — the coalescer's ``flush`` does).
    """

    def __init__(self, directory, *, group_commit: int = 1,
                 segment_bytes: int = DEFAULT_SEGMENT_BYTES,
                 keep_checkpoints: int = 2):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.wal = Wal.open(self.dir / "wal", segment_bytes=segment_bytes,
                            group_commit=group_commit)
        self.ckpt = CheckpointManager(
            str(self.dir / "ckpt"), keep=keep_checkpoints,
            # synchronous writes: an async thread would race log_plan's
            # fsyncs for the durability ordering the battery asserts
            async_write=False,
        )

    # ---------------------------------------------------------------- config
    def write_config(self, config, *, shards: int = 0) -> None:
        """Persist the construction config once (recovery of a store that
        never checkpointed recreates it from this)."""
        path = self.dir / CONFIG_FILE
        if not path.exists():
            path.write_text(json.dumps(
                {"config": dataclasses.asdict(config), "shards": shards}))

    def read_config(self) -> Optional[dict]:
        path = self.dir / CONFIG_FILE
        if not path.exists():
            return None
        return json.loads(path.read_text())

    @property
    def has_history(self) -> bool:
        """Anything already durable here (a fresh client must not silently
        fork it — that is :func:`recover`'s job)."""
        return bool(self.wal.records()) or self.ckpt.latest_step() is not None

    # --------------------------------------------------------------- logging
    def log_plan(self, base_ts: int, codes, keys, values, *,
                 sync: bool = False) -> None:
        """Append one committed plan; durable immediately (``sync``) or
        within the group-commit window."""
        self.wal.append(base_ts, codes, keys, values)
        self.wal.commit(force=sync)

    def sync(self) -> None:
        """Close the group-commit window (one fsync for every pending plan)."""
        self.wal.commit(force=True)

    # ------------------------------------------------------------ checkpoints
    def checkpoint(self, store, step: Optional[int] = None, *,
                   delta: bool = True, compactions: int = 0) -> int:
        """Checkpoint ``store`` and prune fully-covered WAL segments.

        ``delta=True`` writes a delta against the previous checkpoint when
        one exists in this manager (first save is always full); the WAL is
        synced first so the (checkpoint, WAL-tail) pair never has a hole.
        ``step`` defaults to the store clock — saving twice at the same
        clock is a no-op (nothing new to make durable).
        """
        self.sync()
        if step is None:
            step = int(np.asarray(store.ts).max())
        latest = self.ckpt.latest_step()
        if latest is not None and step == latest:
            return step
        if delta and self.ckpt._delta_base is not None:
            self.ckpt.save_store_delta(store, step, compactions=compactions)
        else:
            self.ckpt.save_store(store, step, compactions=compactions)
        self.ckpt.wait()
        self.wal.prune(self.ckpt.store_ts(step))
        return step

    def close(self) -> None:
        self.wal.close()


# ---------------------------------------------------------------------------
# recovery
# ---------------------------------------------------------------------------

def replay(db, records: List[WalRecord]) -> int:
    """Re-apply the WAL tail onto ``db`` at the recorded timestamps.

    Returns the number of plans applied; raises :class:`WalReplayError`
    on a gap or straddle (module docstring).  The caller must not have a
    durability sidecar attached yet — replay must not re-log the log.
    """
    from repro.api.opbatch import OpBatch

    applied = 0
    for rec in records:
        ts = db.ts
        if rec.next_ts <= ts:
            continue                      # inside the checkpoint / duplicate
        if rec.base_ts != ts:
            if rec.base_ts < ts:
                raise WalReplayError(
                    f"record [{rec.base_ts}, {rec.next_ts}) straddles the "
                    f"recovered clock {ts} — checkpoint and WAL disagree")
            raise WalReplayError(
                f"gap: recovered clock {ts} but the next WAL record "
                f"starts at {rec.base_ts}")
        codes = np.array(rec.codes, np.int32)
        keys = np.array(rec.keys, np.int32)
        values = np.array(rec.values, np.int32)
        reads = (codes == OP_SEARCH) | (codes == OP_RANGE)
        codes[reads] = OP_NOP             # identical clock advance, no
        keys[reads] = KEY_MAX             # pagination re-runs (docstring)
        values[reads] = 0
        db.apply(OpBatch(codes, keys, values))
        applied += 1
        if db.ts != rec.next_ts:
            raise WalReplayError(
                f"replayed record [{rec.base_ts}, {rec.next_ts}) left the "
                f"clock at {db.ts}")
    return applied


def recover(durable_dir, *, backend: Optional[str] = None, policy=None,
            group_commit: int = 1,
            segment_bytes: int = DEFAULT_SEGMENT_BYTES):
    """Rebuild the client from a durable directory after a crash.

    Opens the WAL (truncating a torn tail), restores the newest complete
    checkpoint — or recreates the empty store from ``uruv.json`` when
    none exists — replays the WAL tail, and re-attaches the sidecar so
    the recovered client keeps logging into the same directory.  The
    result is bit-identical (values, found masks, version timestamps) to
    the uninterrupted run's confirmed prefix; ``db.recovery`` says what
    happened.
    """
    from repro.api import Uruv, UruvConfig

    dur = Durability(durable_dir, group_commit=group_commit,
                     segment_bytes=segment_bytes)
    info = dur.read_config()
    if info is None:
        raise FileNotFoundError(
            f"{durable_dir}: no {CONFIG_FILE} — not a durable Uruv directory")
    if info.get("shards"):
        raise NotImplementedError(
            "recover() rebuilds single-device clients; sharded durable "
            "stores are not supported")
    step: Optional[int] = dur.ckpt.latest_step()
    if step is not None:
        store, step = dur.ckpt.restore_store(step)
        db = Uruv.from_store(store, backend=backend, policy=policy)
    else:
        db = Uruv(UruvConfig(**info["config"]), backend=backend,
                  policy=policy)
    n = replay(db, dur.wal.records())
    db._attach_durability(dur)
    db.recovery = RecoveryInfo(
        wal=dur.wal.report, checkpoint_step=step,
        replayed_plans=n, recovered_ts=db.ts,
    )
    return db
