"""Write-ahead announce log: CRC-framed segments, group commit, torn tails.

One WAL record is one committed plan — ``(base_ts, codes, keys, values)``
with ``next_ts = base_ts + len(codes)`` — exactly the unit the executors
linearize, so replay at the recorded timestamps reproduces every version
timestamp bit-exactly (DESIGN.md Sec 14).  The on-disk format:

  segment file  wal_<seq:08d>.log
  ------------------------------------------------------------------
  segment header   8s  magic  b"URUVWAL1"
                   <I  seq    (must match the filename)
                   <I  crc32 of the seq field
  record           <I  magic  0x55525543
                   <I  payload length in bytes
                   <I  crc32 of the payload
  record payload   <iiI base_ts, next_ts, n   then codes/keys/values,
                   each ``n`` little-endian int32 words

Durability contract (confirm-after-fsync): a plan's result may only be
confirmed to a client after its record is on disk — the sync ``apply``
path appends + commits before returning, the pipelined path appends at
``Uruv.confirm`` time (a rejected plan is never logged; its replay logs
through ``apply``).  ``group_commit > 1`` relaxes this to a bounded
window: up to ``group_commit - 1`` confirmed plans may await the next
fsync (the classic group-commit throughput trade; ``commit(force=True)``
— and ``Coalescer.flush`` — close the window).

Open semantics (deterministic recover-or-reject, never half a plan):

  * a record that fails its frame checks in the FINAL segment ends the
    log: everything from that offset on is physically truncated and
    reported byte-exactly in :class:`WalReport` (a torn tail is the
    expected result of dying mid-append / pre-fsync);
  * invalid bytes in a NON-final segment are corruption, not a tail —
    later segments hold records the store may have confirmed, so
    truncating here could silently lose acknowledged plans:
    :class:`WalCorruptionError`;
  * duplicate records (a replayed/copied segment) parse fine here and
    are skipped at replay time by the ``next_ts <= store.ts`` rule in
    :mod:`repro.durability.recovery`.
"""

from __future__ import annotations

import dataclasses
import os
import struct
import zlib
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.distributed.fault import crash_point

SEG_MAGIC = b"URUVWAL1"
SEG_HEADER = struct.Struct("<8sII")       # magic, seq, crc32(seq)
REC_MAGIC = 0x55525543                    # "URUC"
REC_HEADER = struct.Struct("<III")        # magic, payload_len, crc32(payload)
PAY_HEADER = struct.Struct("<iiI")        # base_ts, next_ts, n
_SEQ = struct.Struct("<I")

DEFAULT_SEGMENT_BYTES = 4 << 20


class WalCorruptionError(RuntimeError):
    """Invalid bytes somewhere other than the final segment's tail."""


@dataclasses.dataclass(frozen=True)
class WalRecord:
    """One committed plan: replay = apply at ``base_ts`` (Sec 14)."""

    base_ts: int
    next_ts: int
    codes: np.ndarray   # int32 [n]
    keys: np.ndarray    # int32 [n]
    values: np.ndarray  # int32 [n]

    def __len__(self) -> int:
        return int(self.codes.shape[0])


@dataclasses.dataclass
class WalReport:
    """What :func:`Wal.open` found — and exactly what it truncated."""

    n_records: int = 0
    n_segments: int = 0
    truncated_bytes: int = 0          # discarded from the final segment
    truncated_segment: Optional[str] = None
    torn_tail: bool = False

    def __str__(self) -> str:  # pragma: no cover - debugging aid
        return (f"WalReport(records={self.n_records}, "
                f"segments={self.n_segments}, "
                f"truncated={self.truncated_bytes}B"
                f"{' @' + self.truncated_segment if self.torn_tail else ''})")


def _segment_path(directory: Path, seq: int) -> Path:
    return directory / f"wal_{seq:08d}.log"


def _fsync_dir(directory: Path) -> None:
    fd = os.open(directory, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _pack_record(base_ts: int, codes: np.ndarray, keys: np.ndarray,
                 values: np.ndarray) -> bytes:
    codes = np.ascontiguousarray(codes, dtype="<i4")
    keys = np.ascontiguousarray(keys, dtype="<i4")
    values = np.ascontiguousarray(values, dtype="<i4")
    n = codes.shape[0]
    if keys.shape[0] != n or values.shape[0] != n:
        raise ValueError("codes/keys/values must share one announce width")
    payload = (PAY_HEADER.pack(int(base_ts), int(base_ts) + n, n)
               + codes.tobytes() + keys.tobytes() + values.tobytes())
    return REC_HEADER.pack(REC_MAGIC, len(payload),
                           zlib.crc32(payload)) + payload


def _parse_payload(payload: bytes) -> WalRecord:
    base_ts, next_ts, n = PAY_HEADER.unpack_from(payload, 0)
    want = PAY_HEADER.size + 12 * n
    if len(payload) != want or next_ts != base_ts + n:
        raise ValueError("inconsistent record payload")
    off = PAY_HEADER.size
    arrs = [
        np.frombuffer(payload, dtype="<i4", count=n,
                      offset=off + 4 * n * i).astype(np.int32)
        for i in range(3)
    ]
    return WalRecord(base_ts, next_ts, *arrs)


def _scan_segment(path: Path, seq: int) -> Tuple[List[WalRecord], int, int]:
    """Parse one segment -> (records, valid_end_offset, file_size).

    Stops at the first frame that fails any check (short header, bad
    magic, bad CRC, inconsistent payload); the caller decides whether
    that is a torn tail (final segment) or corruption (earlier one).
    """
    data = path.read_bytes()
    if len(data) < SEG_HEADER.size:
        return [], 0, len(data)
    magic, hdr_seq, hdr_crc = SEG_HEADER.unpack_from(data, 0)
    if (magic != SEG_MAGIC or hdr_seq != seq
            or hdr_crc != zlib.crc32(_SEQ.pack(hdr_seq))):
        return [], 0, len(data)
    records: List[WalRecord] = []
    off = SEG_HEADER.size
    while True:
        if off + REC_HEADER.size > len(data):
            break
        magic, length, crc = REC_HEADER.unpack_from(data, off)
        end = off + REC_HEADER.size + length
        if magic != REC_MAGIC or end > len(data):
            break
        payload = data[off + REC_HEADER.size:end]
        if zlib.crc32(payload) != crc:
            break
        try:
            records.append(_parse_payload(payload))
        except ValueError:
            break
        off = end
    return records, off, len(data)


class Wal:
    """Append-only writer + validated reader over one WAL directory.

    Use :meth:`open` (it validates, truncates the torn tail, and
    positions the writer); ``append`` buffers one record, ``commit``
    makes everything appended so far durable (flush + fsync) — the
    fsync-bounded group commit: N appends per commit share one fsync.
    """

    def __init__(self, directory: Path, segments: List[int],
                 records: List[WalRecord], report: WalReport,
                 seg_max_ts: Dict[int, int], *,
                 segment_bytes: int, group_commit: int):
        self.dir = directory
        self.segment_bytes = segment_bytes
        self.group_commit = max(1, int(group_commit))
        self.report = report
        self._records = records
        self._segments = segments
        self._seg_max_ts = seg_max_ts
        self._pending = 0          # plans appended since the last fsync
        self._file = None
        if segments:
            self._seq = segments[-1]
            self._file = open(_segment_path(directory, self._seq), "ab")

    # ------------------------------------------------------------------ open
    @classmethod
    def open(cls, directory, *, segment_bytes: int = DEFAULT_SEGMENT_BYTES,
             group_commit: int = 1) -> "Wal":
        """Validate every segment, truncate the torn tail, open for append.

        Raises :class:`WalCorruptionError` for invalid bytes anywhere but
        the final segment's tail; ``wal.report`` says exactly how many
        bytes (if any) were truncated and from which file.
        """
        directory = Path(directory)
        directory.mkdir(parents=True, exist_ok=True)
        paths = sorted(directory.glob("wal_*.log"))
        report = WalReport(n_segments=len(paths))
        records: List[WalRecord] = []
        seg_max_ts: Dict[int, int] = {}
        kept: List[int] = []
        for i, path in enumerate(paths):
            seq = int(path.stem.split("_")[1])
            recs, valid_end, size = _scan_segment(path, seq)
            final = i == len(paths) - 1
            if valid_end < size:
                if not final:
                    raise WalCorruptionError(
                        f"{path.name}: invalid bytes at offset {valid_end} "
                        f"in a non-final segment ({size - valid_end} bytes); "
                        "later segments may hold confirmed plans — refusing "
                        "to truncate")
                report.truncated_bytes = size - valid_end
                report.truncated_segment = path.name
                report.torn_tail = True
                with open(path, "r+b") as f:
                    f.truncate(valid_end)
            if valid_end < SEG_HEADER.size:
                # not even a valid segment header survived — the file
                # never became a real segment (died inside _open_segment)
                path.unlink()
                continue
            kept.append(seq)
            records.extend(recs)
            if recs:
                seg_max_ts[seq] = recs[-1].next_ts
        report.n_records = len(records)
        return cls(directory, kept, records, report, seg_max_ts,
                   segment_bytes=segment_bytes, group_commit=group_commit)

    # ---------------------------------------------------------------- reading
    def records(self) -> List[WalRecord]:
        """Every validated record, in append order (replay input)."""
        return list(self._records)

    @property
    def last_ts(self) -> Optional[int]:
        return self._records[-1].next_ts if self._records else None

    # ---------------------------------------------------------------- writing
    def _open_segment(self, seq: int) -> None:
        path = _segment_path(self.dir, seq)
        f = open(path, "xb")
        f.write(SEG_HEADER.pack(SEG_MAGIC, seq, zlib.crc32(_SEQ.pack(seq))))
        f.flush()
        os.fsync(f.fileno())
        _fsync_dir(self.dir)               # the new name itself is durable
        self._segments.append(seq)
        self._seq = seq
        self._file = f

    def append(self, base_ts: int, codes, keys, values) -> None:
        """Buffer one plan record (no fsync — that is :meth:`commit`'s).

        The two writes around the ``wal.mid_append`` crash point are the
        fault-injection battery's torn-record generator: dying there
        leaves exactly half a record on disk, which the next
        :meth:`open` must truncate and report.
        """
        if self._file is None:
            self._open_segment(1)
        elif self._file.tell() >= self.segment_bytes:
            self.commit(force=True)        # never strand records behind
            self._file.close()             # a rotation boundary
            self._open_segment(self._seq + 1)
        rec = _pack_record(base_ts, np.asarray(codes), np.asarray(keys),
                           np.asarray(values))
        half = len(rec) // 2
        self._file.write(rec[:half])
        crash_point("wal.mid_append", flush=self._file.flush)
        self._file.write(rec[half:])
        self._records.append(_parse_payload(rec[REC_HEADER.size:]))
        self._seg_max_ts[self._seq] = self._records[-1].next_ts
        self._pending += 1

    def commit(self, force: bool = True) -> bool:
        """Make every appended record durable (flush + one fsync).

        ``force=False`` is the group-commit gate: fsync only once
        ``group_commit`` plans are pending, else leave them buffered.
        Returns whether an fsync happened.
        """
        if self._pending == 0 or self._file is None:
            return False
        if not force and self._pending < self.group_commit:
            return False
        self._file.flush()
        crash_point("wal.pre_fsync")
        os.fsync(self._file.fileno())
        crash_point("wal.post_fsync")
        self._pending = 0
        return True

    @property
    def pending(self) -> int:
        """Plans appended but not yet fsynced (the group-commit window)."""
        return self._pending

    # -------------------------------------------------------------------- gc
    def prune(self, min_ts: int) -> int:
        """Drop whole segments fully covered by a checkpoint at ``min_ts``
        (every record's ``next_ts <= min_ts``); never the open segment.
        Returns the number of segments removed."""
        removed = 0
        for seq in list(self._segments[:-1]):
            if self._seg_max_ts.get(seq, min_ts + 1) <= min_ts:
                _segment_path(self.dir, seq).unlink(missing_ok=True)
                self._segments.remove(seq)
                self._seg_max_ts.pop(seq, None)
                removed += 1
        if removed:
            _fsync_dir(self.dir)
        return removed

    def close(self) -> None:
        if self._file is not None:
            self.commit(force=True)
            self._file.close()
            self._file = None
