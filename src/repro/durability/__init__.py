"""Durability: write-ahead announce log + delta checkpoints + recovery.

The announce array is already a replayable record: every committed plan
is `(base_ts, codes, keys, values)` and the store's `op_ts` plumbing
makes re-application at the original timestamps bit-exact (the same
property that makes sharded == local).  This package turns that into a
durability story (DESIGN.md Sec 14):

  * :mod:`repro.durability.wal` — append-only CRC-framed segments with
    fsync-bounded group commit and torn-tail detection-and-truncate.
  * :mod:`repro.durability.recovery` — the `Durability` sidecar the
    ``repro.api`` executors log through, and :func:`recover`: restore
    the last complete checkpoint (full or base+delta chain, see
    ``repro.checkpoint.manager``) and replay the WAL tail at its
    recorded timestamps.

Everything on the replay path is deterministic by construction — no wall
clock, no host RNG (gated by the ``determinism`` uruvlint rule, whose
scope includes this package).
"""

from repro.durability.wal import (  # noqa: F401
    Wal, WalCorruptionError, WalRecord, WalReport,
)
from repro.durability.recovery import (  # noqa: F401
    Durability, WalReplayError, recover,
)
