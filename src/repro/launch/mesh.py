"""Production mesh builders.

Functions (never module-level constants) so importing this module never
touches jax device state.
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod; the multi-pod mesh adds a leading pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(AxisType.Auto,) * len(axes)
    )


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over local devices (tests, examples)."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(AxisType.Auto, AxisType.Auto),
    )
