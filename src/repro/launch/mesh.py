"""Production mesh builders.

Functions (never module-level constants) so importing this module never
touches jax device state.  Mesh construction goes through
``repro.compat.make_mesh`` so the same code runs on jax versions with and
without ``jax.sharding.AxisType`` (DESIGN.md Sec 2 notes the compat rule).
"""

from __future__ import annotations

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod; the multi-pod mesh adds a leading pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over local devices (tests, examples)."""
    return make_mesh((data, model), ("data", "model"))
