"""Roofline analysis from compiled dry-run artifacts.

XLA's ``cost_analysis()`` counts while-loop bodies ONCE (scan'd layers would
be undercounted ~L-fold), so this module re-derives costs from the
partitioned HLO text with **loop-aware multipliers**:

  1. parse computations + an instruction name -> bytes map,
  2. per computation: dot FLOPs (2 * result_elems * contracted_size),
     HBM bytes (operands + results at fusion boundaries — post-optimization
     top-level ops ARE the HBM traffic), collective bytes by type,
  3. walk the call graph from ENTRY: while bodies multiply by the trip
     count parsed from their condition (scan conditions compare the
     induction variable against a constant), conditionals take a branch
     weight (upper bound 1.0 by default; zamba's shared-attention branch
     runs 1/hybrid_attn_every of iterations and is corrected analytically),
  4. roofline terms per chip against v5e constants.

Terms (seconds/step/chip):
  compute    = dot_flops / 197e12          (bf16 MXU peak)
  memory     = hbm_bytes / 819e9           (HBM bandwidth)
  collective = wire_bytes / (ici_links * 50e9)
"""

from __future__ import annotations

import dataclasses
import gzip
import json
import re
from pathlib import Path
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 / chip (v5e)
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link
ICI_LINKS = 4                # usable links per chip on a 2D torus (v5e)

DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "pred": 1, "s8": 1, "u8": 1, "f64": 8, "s16": 2, "u16": 2, "f8e4m3": 1,
    "f8e5m2": 1, "c64": 8, "token": 0, "s4": 1, "u4": 1,
}

WIRE_FACTOR = {
    "all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
    "all-to-all": 1.0, "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->.*\{")
_OPND_RE = re.compile(r"%([\w.\-]+)")

FREE_OPS = (
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
    # dtype legalization: XLA-CPU materializes bf16<->f32 converts that fuse
    # away (or never exist) on TPU — counting them would bias the memory
    # term by the backend, not the program (EXPERIMENTS.md methodology).
    "convert",
)

# ops with in-place / sparse-access semantics: count moved bytes, not the
# full buffers they are threaded through
INPLACE_OPS = ("dynamic-update-slice", "scatter")
SPARSE_READ_OPS = ("gather", "dynamic-slice")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _fusion_bytes(rhs: str, opnd_list, res_bytes: int, name_bytes,
                  comps) -> float:
    """HBM traffic of one fusion op, aware of fused sparse access."""
    cm = re.search(r"calls=%?([\w.\-]+)", rhs)
    body = comps.get(cm.group(1)) if cm else None
    if body is None:
        return res_bytes + sum(name_bytes.get(o, 0) for o in set(opnd_list))

    # fusion-internal layout ops are virtual (folded into the generated
    # access pattern); "copy" is only free INSIDE a fusion
    LAYOUT_OPS = ("reverse", "bitcast", "transpose", "reshape", "broadcast",
                  "copy")
    # parse body: instruction records
    insts = []                 # (name, op, operands, result_bytes, is_root)
    by_name = {}
    for line in body:
        m = _DEF_RE.match(line)
        if not m:
            continue
        nm, brhs = m.group(1), m.group(2)
        opm = re.search(r"\b([a-z][\w\-]*)\(", brhs)
        op = opm.group(1) if opm else ""
        args = brhs[brhs.find("(") + 1:] if "(" in brhs else ""
        used = _OPND_RE.findall(args.split(")")[0]) if args else []
        rb = _shape_bytes(brhs.split(" ", 1)[0])
        rec = (nm, op, used, rb, line.strip().startswith("ROOT"), brhs)
        insts.append(rec)
        by_name[nm] = rec

    users: Dict[str, list] = {}
    for rec in insts:
        for o in rec[2]:
            users.setdefault(o, []).append(rec)

    def sparse_bytes(pname) -> Optional[int]:
        """If pname is consumed only through layout ops ending in
        dynamic-slice/gather (as the sliced operand), return slice bytes."""
        total = 0
        frontier = [pname]
        seen = set()
        while frontier:
            cur = frontier.pop()
            if cur in seen:
                continue
            seen.add(cur)
            for nm, op, used, rb, _, _ in users.get(cur, []):
                if (op in ("dynamic-slice", "gather", "slice")
                        and used and used[0] == cur):
                    total += rb
                elif op == "dynamic-update-slice" and used and used[0] == cur:
                    # in-place update target: aliased, zero read traffic
                    # (the update's write is charged via root_dus)
                    frontier.append(nm)
                elif op in LAYOUT_OPS:
                    frontier.append(nm)
                else:
                    return None
        return total

    total = float(res_bytes)
    root_dus = 0
    for nm, op, used, rb, is_root, brhs in insts:
        if is_root and op == "dynamic-update-slice" and len(used) > 1:
            urec = by_name.get(used[1])
            root_dus = urec[3] if urec else 0
    for nm, op, used, rb, is_root, brhs in insts:
        if op != "parameter":
            continue
        pi = re.search(r"parameter\((\d+)\)", brhs)
        if not pi:
            continue
        idx = int(pi.group(1))
        if idx >= len(opnd_list):
            continue
        full = name_bytes.get(opnd_list[idx], 0)
        sb = sparse_bytes(nm)
        if sb is not None:
            total += min(full, 2 * sb)   # sparse/aliased access (0 allowed)
        else:
            total += full
    if root_dus:
        total += root_dus - res_bytes     # in-place root update
        total = max(total, 0.0)
    return total


def name_type_of(body_lines, name: str) -> str:
    if name is None:
        return ""
    for line in body_lines:
        m = _DEF_RE.match(line)
        if m and m.group(1) == name:
            return m.group(2).split(" ", 1)[0]
    return ""


@dataclasses.dataclass
class CompCost:
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    children: List[Tuple[str, str, float]] = dataclasses.field(
        default_factory=list)  # (kind, comp_name, weight)


def parse_hlo(text: str, branch_weight: float = 1.0) -> Dict:
    """Returns loop-aware totals: {'flops','hbm_bytes','coll_bytes':{}}."""
    # ---- split into computations -----------------------------------------
    # Header lines look like:  [ENTRY] %name (params...) -> result { ... }
    # (params may be nested tuple types, so match token-wise, not by regex
    # over the paren group).
    comps: Dict[str, List[str]] = {}
    entry = None
    cur = None
    for line in text.splitlines():
        s = line.strip()
        if s.endswith("{") and "->" in s and "=" not in s.split("(")[0]:
            toks = s.split()
            name = toks[1] if toks[0] == "ENTRY" else toks[0]
            cur = name.lstrip("%")
            comps[cur] = []
            if toks[0] == "ENTRY":
                entry = cur
            continue
        if s == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)

    # ---- instruction shapes (module-wide name -> result bytes) ------------
    name_bytes: Dict[str, int] = {}
    name_type: Dict[str, str] = {}
    for lines in comps.values():
        for line in lines:
            m = _DEF_RE.match(line)
            if not m:
                continue
            name, rhs = m.group(1), m.group(2)
            tpart = rhs.split(" ", 1)[0]
            # tuple results: "(f32[...], ...)"; strip to inner
            name_bytes[name] = _shape_bytes(rhs[: rhs.find(")") + 1]
                                            if rhs.startswith("(") else tpart)
            name_type[name] = rhs

    # ---- trip counts: condition computation -> max int constant ----------
    def trip_of(cond_name: str) -> int:
        best = 1
        for line in comps.get(cond_name, []):
            for m in re.finditer(r"constant\((\d+)\)", line):
                best = max(best, int(m.group(1)))
        return best

    # ---- per-computation local costs + call edges -------------------------
    costs: Dict[str, CompCost] = {}
    for cname, lines in comps.items():
        c = CompCost()
        for line in lines:
            m = _DEF_RE.match(line)
            if not m:
                continue
            name, rhs = m.group(1), m.group(2)
            opcode_m = re.search(r"\b([a-z][\w\-]*)\(", rhs)
            opcode = opcode_m.group(1) if opcode_m else ""
            if opcode == "while":
                bm = re.search(r"body=%?([\w.\-]+)", rhs)
                cm = re.search(r"condition=%?([\w.\-]+)", rhs)
                if bm and cm:
                    c.children.append(("while", bm.group(1),
                                       float(trip_of(cm.group(1)))))
                continue
            if opcode == "conditional":
                for br in re.finditer(
                    r"(?:true_computation|false_computation|"
                    r"branch_computations=\{[^}]*)=?%?([\w.\-]+)", rhs
                ):
                    c.children.append(("cond", br.group(1), branch_weight))
                # also handle branch_computations={%a, %b}
                bc = re.search(r"branch_computations=\{([^}]*)\}", rhs)
                if bc:
                    for nm in _OPND_RE.findall(bc.group(1)):
                        c.children.append(("cond", nm, branch_weight))
                continue
            if opcode == "call":
                tm = re.search(r"to_apply=%?([\w.\-]+)", rhs)
                if tm:
                    c.children.append(("call", tm.group(1), 1.0))
                continue
            if opcode in FREE_OPS or not opcode:
                continue
            res_bytes = name_bytes.get(name, 0)
            # collectives: wire bytes, not HBM
            coll = None
            for k in WIRE_FACTOR:
                if opcode == k or opcode.startswith(k):
                    coll = k
                    break
            if coll:
                c.coll_bytes[coll] = (
                    c.coll_bytes.get(coll, 0.0)
                    + res_bytes * WIRE_FACTOR[coll]
                )
                continue
            # operand bytes (dedup per instruction)
            args = rhs[rhs.find("(") + 1 : ]
            # strip attributes after the closing paren of operand list
            depth, end = 0, len(args)
            for i, ch in enumerate(args):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    if depth == 0:
                        end = i
                        break
                    depth -= 1
            opnd_list = _OPND_RE.findall(args[:end])
            opnds = set(opnd_list)
            if opcode in INPLACE_OPS:
                # in-place update: traffic = the update operand (+ indices),
                # not the buffer threaded through (XLA aliases it)
                upd = opnd_list[1] if len(opnd_list) > 1 else None
                c.hbm_bytes += 2 * name_bytes.get(upd, 0)
                continue
            if opcode in SPARSE_READ_OPS:
                # sparse read: traffic = gathered result (+ indices), not
                # the whole table
                c.hbm_bytes += 2 * res_bytes
                continue
            if opcode == "fusion":
                # fusion boundary = HBM traffic, but params consumed ONLY
                # through dynamic-slice/gather inside the fusion are sparse
                # reads (count the sliced bytes, not the whole buffer), and
                # a dynamic-update-slice root aliases in place.
                c.hbm_bytes += _fusion_bytes(
                    rhs, opnd_list, res_bytes, name_bytes, comps
                )
                continue
            op_bytes = sum(name_bytes.get(o, 0) for o in opnds)
            c.hbm_bytes += res_bytes + op_bytes
            if opcode == "dot":
                cd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
                lhs = next(iter(_OPND_RE.findall(args[:end])), None)
                contracted = 1
                if cd and lhs and lhs in name_type:
                    lhs_shape = _SHAPE_RE.search(name_type[lhs])
                    if lhs_shape:
                        dims = [int(d) for d in lhs_shape.group(2).split(",")
                                if d]
                        for di in cd.group(1).split(","):
                            if di and int(di) < len(dims):
                                contracted *= dims[int(di)]
                res_elems = 0
                rm = _SHAPE_RE.search(rhs.split(" ", 1)[0])
                if rm:
                    res_elems = 1
                    for d in rm.group(2).split(","):
                        if d:
                            res_elems *= int(d)
                c.dot_flops += 2.0 * res_elems * contracted
        costs[cname] = c

    # ---- effective multipliers from ENTRY ---------------------------------
    mult: Dict[str, float] = {entry: 1.0}
    order = [entry]
    seen = {entry}
    while order:
        nxt = []
        for cn in order:
            for kind, child, w in costs.get(cn, CompCost()).children:
                if child not in comps:
                    continue
                mult[child] = mult.get(child, 0.0) + mult.get(cn, 0.0) * w
                if child not in seen:
                    seen.add(child)
                    nxt.append(child)
        order = nxt

    flops = sum(costs[c].dot_flops * m for c, m in mult.items() if c in costs)
    hbm = sum(costs[c].hbm_bytes * m for c, m in mult.items() if c in costs)
    coll: Dict[str, float] = {}
    for cn, m in mult.items():
        for k, v in costs.get(cn, CompCost()).coll_bytes.items():
            coll[k] = coll.get(k, 0.0) + v * m
    return {"flops": flops, "hbm_bytes": hbm, "coll_bytes": coll,
            "computations": len(comps)}


# ---------------------------------------------------------------------------
# analytic MODEL_FLOPS (the "useful compute" yardstick)
# ---------------------------------------------------------------------------

def model_params(cfg) -> Tuple[float, float]:
    """(total params, active params) from exact eval_shape sizes."""
    import jax
    from repro.models.registry import param_shapes

    tree = param_shapes(cfg)
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    total = active = 0.0
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        n = 1.0
        for d in leaf.shape:
            n *= d
        if "embed" in name:
            continue                      # 6ND convention: non-embedding
        total += n
        if cfg.moe and re.search(r"moe/(w1|w2|w3)$", name):
            active += n * cfg.moe.top_k / cfg.moe.n_experts
        else:
            active += n
    return total, active


def model_flops(cfg, shape) -> float:
    """Global MODEL_FLOPS per step: 6ND train / 2ND prefill / 2N decode,
    plus causal attention terms."""
    N, N_act = model_params(cfg)
    B, S = shape.global_batch, shape.seq_len
    hd = cfg.resolved_head_dim
    attn_layers = (
        0 if (cfg.xlstm is not None) else
        (cfg.n_layers // cfg.hybrid_attn_every if cfg.hybrid_attn_every
         else cfg.n_layers)
    )
    if shape.kind == "train":
        flops = 6.0 * N_act * B * S
        flops += 6.0 * attn_layers * B * S * S * cfg.n_heads * hd  # causal/2*12
        if cfg.window and not cfg.encoder_only:
            # local layers attend to <= window keys
            local = attn_layers - (attn_layers // cfg.global_every
                                   if cfg.global_every else 0)
            flops -= 6.0 * local * B * S * max(0, S - cfg.window) \
                * cfg.n_heads * hd
        return flops
    if shape.kind == "prefill":
        flops = 2.0 * N_act * B * S
        flops += 2.0 * attn_layers * B * S * S * cfg.n_heads * hd / 2
        return flops
    # decode: one token/seq; attention reads the S-long cache
    flops = 2.0 * N_act * B
    flops += 4.0 * attn_layers * B * S * cfg.n_heads * hd
    return flops


# ---------------------------------------------------------------------------
# cell -> roofline record
# ---------------------------------------------------------------------------

def analyze_cell(json_path: Path, branch_weight: Optional[float] = None
                 ) -> Optional[Dict]:
    from repro.config import SHAPES, get_arch

    rec = json.loads(json_path.read_text())
    if rec.get("status") != "OK":
        return rec
    cfg = get_arch(rec["arch"])
    shape = SHAPES[rec["shape"]]
    if branch_weight is None:
        branch_weight = (1.0 / cfg.hybrid_attn_every
                         if cfg.hybrid_attn_every else 1.0)
    hlo_path = json_path.parent / (json_path.stem + ".hlo.gz")
    if not hlo_path.exists():
        return {**rec, "status": "NO_HLO"}
    with gzip.open(hlo_path, "rt") as f:
        parsed = parse_hlo(f.read(), branch_weight=branch_weight)

    chips = rec["n_chips"]
    t_compute = parsed["flops"] / PEAK_FLOPS            # per-chip program
    t_memory = parsed["hbm_bytes"] / HBM_BW
    wire = sum(parsed["coll_bytes"].values())
    t_coll = wire / (ICI_LINKS * ICI_BW)
    mf = model_flops(cfg, shape)
    dom = max(
        (("compute", t_compute), ("memory", t_memory),
         ("collective", t_coll)),
        key=lambda kv: kv[1],
    )[0]
    step_time = max(t_compute, t_memory, t_coll)
    mfu = (mf / chips / PEAK_FLOPS) / step_time if step_time else 0.0
    return {
        **{k: rec[k] for k in ("arch", "shape", "mesh", "n_chips")},
        "status": "OK",
        "hlo_flops_per_chip": parsed["flops"],
        "hbm_bytes_per_chip": parsed["hbm_bytes"],
        "coll_bytes_per_chip": wire,
        "coll_by_type": parsed["coll_bytes"],
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "bottleneck": dom,
        "model_flops_global": mf,
        "useful_ratio": mf / chips / max(parsed["flops"], 1.0),
        "roofline_fraction_mfu": mfu,
        "temp_bytes": rec.get("memory_analysis", {}).get(
            "temp_size_in_bytes"),
    }


def analyze_all(results_dir: Path, mesh: str = "single") -> List[Dict]:
    out = []
    for p in sorted(results_dir.glob(f"*__{mesh}.json")):
        r = analyze_cell(p)
        if r is not None:
            out.append(r)
    return out


def table(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "bottleneck | MODEL/HLO | roofline frac |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        if r.get("status") != "OK":
            lines.append(
                f"| {r.get('arch','?')} | {r.get('shape','?')} | "
                f"{r.get('mesh','?')} | - | - | - | "
                f"{r.get('status')}: {r.get('reason','')} | - | - |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} "
            f"| {r['t_collective_s']:.3e} | {r['bottleneck']} "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction_mfu']:.2%} |")
    return "\n".join(lines)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    rows = analyze_all(Path(args.dir), args.mesh)
    if args.json:
        print(json.dumps(rows, indent=2))
    else:
        print(table(rows))
