"""CLI trainer.

  PYTHONPATH=src python -m repro.launch.train --arch llama3_2_1b \
      --reduced --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

``--reduced`` trains the smoke-scale config (CPU-friendly); full configs
are intended for the production mesh (see repro.launch.dryrun for the
multi-pod distribution proof).
"""

from __future__ import annotations

import argparse
import dataclasses

from repro.config import get_arch
from repro.train.loop import TrainLoopConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--d-model", type=int, default=0,
                    help="override width (e.g. ~100M quickstart)")
    ap.add_argument("--layers", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.d_model:
        cfg = dataclasses.replace(cfg, d_model=args.d_model)
    if args.layers:
        cfg = dataclasses.replace(cfg, n_layers=args.layers)

    loop = TrainLoopConfig(
        batch_size=args.batch, seq_len=args.seq, total_steps=args.steps,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
    )
    out = train(cfg, loop)
    print(
        f"done: {out['steps_per_s']:.2f} steps/s, "
        f"loss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f}"
    )


if __name__ == "__main__":
    main()
