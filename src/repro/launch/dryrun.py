import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: builds the
production mesh from placeholder devices, lowers the real train/serve step
with ShapeDtypeStruct inputs (no allocation), compiles, and records
memory/cost/collective analyses per cell under experiments/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch llama3_2_1b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all            # every applicable cell (cached)
  python -m repro.launch.dryrun --all --force    # recompute
"""

import argparse
import json
import re
import subprocess
import sys
import time
import traceback
from pathlib import Path

RESULTS_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

COLLECTIVE_RE = re.compile(
    r"=\s+(\w+)\[([\d,]*)\]"          # result dtype[shape]
    r"[^=]*?\b"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)\b"
)

DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "pred": 1, "s8": 1, "u8": 1, "f64": 8, "s16": 2, "u16": 2,
}

# bytes-on-wire factor per algorithm (ring; group size n -> (n-1)/n ~= 1)
WIRE_FACTOR = {
    "all-reduce": 2.0,          # reduce-scatter + all-gather
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def collective_bytes(hlo_text: str):
    """Sum bytes moved per collective type from partitioned HLO text."""
    per_type = {}
    count = {}
    for m in COLLECTIVE_RE.finditer(hlo_text):
        dt, shape_s, op = m.group(1), m.group(2), m.group(3)
        elems = 1
        if shape_s:
            for p in shape_s.split(","):
                if p:
                    elems *= int(p)
        nbytes = elems * DTYPE_BYTES.get(dt, 4) * WIRE_FACTOR[op]
        per_type[op] = per_type.get(op, 0.0) + nbytes
        count[op] = count.get(op, 0) + 1
    return per_type, count


def run_cell(arch_id: str, shape_id: str, mesh_kind: str) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.config import SHAPES, get_arch, shape_applicable
    from repro.distributed import sharding as shd
    from repro.distributed.ctx import use_mesh
    from repro.launch.mesh import make_production_mesh
    from repro.models.registry import input_specs, param_shapes
    from repro.optim import adamw
    from repro.train import steps

    cfg = get_arch(arch_id)
    shape = SHAPES[shape_id]
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return {"status": "SKIP", "reason": reason}

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    policy = shd.ShardingPolicy(
        fsdp=True, sequence_parallel=cfg.sequence_parallel
    )
    long_ctx = shape.name == "long_500k"

    t0 = time.time()
    pshapes = param_shapes(cfg)
    pshard = shd.param_shardings(pshapes, mesh, policy)
    batch_sds = input_specs(cfg, shape)
    bspecs = shd.batch_specs(batch_sds, mesh, long_context=long_ctx)
    bshard = shd.named(bspecs, mesh)

    def with_sharding(sds_tree, shard_tree):
        return jax.tree.map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            sds_tree, shard_tree,
        )

    with use_mesh(mesh, sequence_parallel=cfg.sequence_parallel and not long_ctx,
                  long_context=long_ctx):
        if shape.kind == "train":
            opt_cfg = adamw.AdamWConfig()
            step_fn = steps.make_train_step(cfg, opt_cfg)
            state_sds = jax.eval_shape(
                lambda p: steps.TrainState(
                    params=p, opt=adamw.init(p),
                    step=jnp.zeros((), jnp.int32)),
                pshapes,
            )
            scalar = jax.NamedSharding(mesh, shd.P())
            state_shard = steps.TrainState(
                params=pshard,
                opt=adamw.OptState(m=pshard, v=pshard, step=scalar),
                step=scalar,
            )
            args = (
                with_sharding(state_sds, state_shard),
                with_sharding(batch_sds, bshard),
            )
            jitted = jax.jit(step_fn, donate_argnums=(0,))
        elif shape.kind == "prefill":
            step_fn = steps.make_prefill_step(cfg)
            args = (
                with_sharding(pshapes, pshard),
                with_sharding(batch_sds, bshard),
            )
            jitted = jax.jit(step_fn)
        else:  # decode
            step_fn = steps.make_serve_step(cfg)
            args = (
                with_sharding(pshapes, pshard),
                with_sharding(batch_sds, bshard),
            )
            jitted = jax.jit(step_fn, donate_argnums=(1,))

        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            if hasattr(ma, k):
                mem[k] = int(getattr(ma, k))
    except Exception as e:  # pragma: no cover
        mem["error"] = str(e)

    cost = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        for k, v in ca.items():
            if k in ("flops", "bytes accessed", "optimal_seconds") or \
               k.startswith("bytes accessed"):
                cost[k] = float(v)
    except Exception as e:  # pragma: no cover
        cost["error"] = str(e)

    text = compiled.as_text()
    per_type, counts = collective_bytes(text)

    # persist the partitioned HLO for offline roofline parsing
    import gzip
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    hlo_path = RESULTS_DIR / f"{arch_id}__{shape_id}__{mesh_kind}.hlo.gz"
    with gzip.open(hlo_path, "wt") as f:
        f.write(text)

    n_chips = 1
    for v in mesh.shape.values():
        n_chips *= v

    return {
        "status": "OK",
        "arch": arch_id,
        "shape": shape_id,
        "mesh": mesh_kind,
        "mesh_shape": dict(mesh.shape),
        "n_chips": n_chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": mem,
        "cost_analysis": cost,
        "collective_bytes": per_type,
        "collective_counts": counts,
        "hlo_bytes": len(text),
    }


def cell_path(arch_id, shape_id, mesh_kind) -> Path:
    return RESULTS_DIR / f"{arch_id}__{shape_id}__{mesh_kind}.json"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=int, default=3000)
    args = ap.parse_args()

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)

    if not args.all:
        out = run_cell(args.arch, args.shape, args.mesh)
        p = cell_path(args.arch, args.shape, args.mesh)
        p.write_text(json.dumps(out, indent=2))
        print(json.dumps(out, indent=2))
        sys.exit(0 if out["status"] in ("OK", "SKIP") else 1)

    # driver: every cell in its own subprocess (isolation + resumability)
    from repro.config import ARCH_IDS, SHAPES

    todo = [
        (a, s, m)
        for a in ARCH_IDS
        for s in SHAPES
        for m in ("single", "multi")
    ]
    failures = []
    for a, s, m in todo:
        p = cell_path(a, s, m)
        if p.exists() and not args.force:
            st = json.loads(p.read_text()).get("status")
            print(f"[cache] {a} {s} {m}: {st}")
            continue
        print(f"[run  ] {a} {s} {m} ...", flush=True)
        t0 = time.time()
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", a, "--shape", s, "--mesh", m],
            capture_output=True, text=True, timeout=args.timeout,
            env=dict(os.environ, PYTHONPATH="src"),
            cwd=str(RESULTS_DIR.parents[1]),
        )
        dt = time.time() - t0
        if r.returncode != 0:
            failures.append((a, s, m))
            p.write_text(json.dumps({
                "status": "FAIL", "arch": a, "shape": s, "mesh": m,
                "stderr": r.stderr[-4000:],
            }, indent=2))
            print(f"[FAIL ] {a} {s} {m} ({dt:.0f}s)\n{r.stderr[-1500:]}")
        else:
            st = json.loads(p.read_text()).get("status")
            print(f"[done ] {a} {s} {m}: {st} ({dt:.0f}s)")
    print(f"\n{len(failures)} failures: {failures}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
