"""CLI server driver: batched generation through the continuous-batching
engine (reduced configs on CPU; the full-config serve path is proven by the
decode dry-run cells).

  PYTHONPATH=src python -m repro.launch.serve --arch llama3_2_1b --reduced \
      --requests 6 --max-new 8
"""

from __future__ import annotations

import argparse
import time

import numpy as np
import jax

from repro.config import get_arch
from repro.models.registry import get_model
from repro.serve.engine import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    api = get_model(cfg)
    if api.decode_step is None:
        raise SystemExit(f"{cfg.name} is encoder-only: no serve path")

    params = api.init(cfg, jax.random.key(0))
    eng = Engine(cfg, params, n_slots=args.slots, max_len=args.max_len)

    rng = np.random.default_rng(0)
    shared_prefix = rng.integers(0, cfg.vocab, 6).tolist()
    reqs = []
    for i in range(args.requests):
        prompt = shared_prefix + rng.integers(0, cfg.vocab, 3 + i % 3).tolist()
        reqs.append(Request(rid=i, prompt=prompt, max_new=args.max_new))

    t0 = time.time()
    eng.run(reqs)
    dt = time.time() - t0
    toks = sum(len(r.out) for r in reqs)
    for r in reqs:
        print(f"req {r.rid}: prompt {len(r.prompt)} "
              f"reused_prefix {r.prefix_reused} out {r.out}")
    print(f"{toks} tokens in {dt:.2f}s = {toks/dt:.1f} tok/s "
          f"(batched decode, {args.slots} slots)")
    print(f"prefix-table entries: {len(eng.snapshot_view())}")


if __name__ == "__main__":
    main()
