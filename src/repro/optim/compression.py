"""Gradient compression for data-parallel all-reduce.

Two production tricks with error feedback (EF keeps convergence):

  * top-k sparsification — keep the k largest-|g| entries per leaf; the
    residual feeds back into the next step's gradient.
  * int8 quantization — per-leaf absmax scaling.

``compress_grads`` / ``decompress`` simulate the wire format for the pjit
path (XLA owns the all-reduce; the numerics are what matters for tests).
``compressed_psum`` is the real wire-level variant for shard_map loops:
quantize -> psum(int32 accum) -> dequantize, cutting DP all-reduce bytes 4x
(bf16->s8) — measured in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    kind: str = "none"          # none | topk | int8
    topk_ratio: float = 0.01    # keep top 1%


def init_error(params) -> Dict:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(cfg: CompressionConfig, grads, error):
    """Returns (compressed-then-decompressed grads, new error feedback)."""
    if cfg.kind == "none":
        return grads, error

    def one(g, e):
        g = g.astype(jnp.float32) + e
        if cfg.kind == "topk":
            k = max(1, int(g.size * cfg.topk_ratio))
            flat = g.reshape(-1)
            thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
            keep = jnp.abs(flat) >= thresh
            sent = jnp.where(keep, flat, 0.0).reshape(g.shape)
        elif cfg.kind == "int8":
            scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
            q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
            sent = q.astype(jnp.float32) * scale
        else:
            raise ValueError(cfg.kind)
        return sent, g - sent

    flat, treedef = jax.tree.flatten(grads)
    eflat = jax.tree.leaves(error)
    out = [one(g, e) for g, e in zip(flat, eflat)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def compressed_psum(g: jax.Array, axis_name: str) -> jax.Array:
    """int8-on-the-wire psum for shard_map DP loops."""
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    scale = jax.lax.pmax(scale, axis_name)          # shared scale
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return total.astype(jnp.float32) * scale / n
