"""AdamW with decoupled weight decay, global-norm clipping, LR schedules.

Optimizer state is a pytree congruent with params, so it inherits the
param sharding (TP/FSDP) unchanged.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    m: Dict
    v: Dict
    step: jax.Array


def _decay_mask(params) -> Dict:
    """No weight decay on 1-D params (norm scales, biases, gates)."""
    return jax.tree.map(lambda p: p.ndim > 1, params)


def init(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return OptState(m=zeros, v=jax.tree.map(jnp.copy, zeros),
                    step=jnp.zeros((), jnp.int32))


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / max(1, cfg.total_steps - cfg.warmup_steps),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(x.astype(jnp.float32) ** 2) for x in leaves)
    )


def update(
    cfg: AdamWConfig, params, grads, state: OptState
) -> Tuple[Dict, OptState, Dict]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    step = state.step + 1
    lr = schedule(cfg, state.step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    mask = _decay_mask(params)

    def upd(p, g, m, v, dm):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if dm:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    flat_mask = jax.tree.leaves(mask)
    out = [upd(*xs) for xs in zip(flat_p, flat_g, flat_m, flat_v, flat_mask)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(new_m, new_v, step), metrics
