"""ShardedUruv — key-range-partitioned Uruv over a mesh axis (shard_map).

Scaling the paper's store across chips: the key space is range-partitioned;
every device owns one UruvStore shard (all store arrays carry a leading
device axis, sharded over ``axis_name``).  Bulk ADT calls are SPMD programs:

  apply :  ONE mixed-op device pass per shard (`store.bulk_apply`).  Op i of
           the global announce array runs at global timestamp ``base + i``
           on whichever shard owns its key (the per-op timestamp plumbing of
           DESIGN.md Sec 3), so the sharded linearization is bit-identical
           to the single-device one.  Two distributions:
             * replicated (``make_apply``)        — every shard scans the full
               announce array and NOPs the ops it does not own (the
               paper-faithful "every thread reads the whole stateArray";
               collective bytes O(G * devices)).
             * routed     (``make_routed_apply``) — the announce array arrives
               *sharded*; an all_to_all ships each op to its owner, which
               applies its subset at the ops' original global timestamps.
               Collective bytes O(G * route_factor).  Capacity overflow
               (a shard owed more than its routing budget) returns ok=False;
               the host falls back to the replicated pass.
  update:  thin wrapper deriving INSERT/DELETE codes (legacy API).
  lookup:  all_gather -> owner answers -> psum-combine (one-hot by ownership).
  range :  batched fan-out/gather (``make_range_apply``) — every shard runs
           ONE `store.bulk_range` pass over its owned leaves at the global
           snapshots, the per-shard result blocks are all_gather'ed and
           merged by key ON DEVICE (frontier-clamped so paginated results
           stay exact), bit-identical to the single-device `bulk_range`
           including version-timestamp resolution.  The legacy per-interval
           ``range`` op of :func:`make_ops` remains for the Q=1 path.

The global clock stays consistent without communication: every shard
advances its local ts to ``base + G`` per batch regardless of how many ops
it owns, so timestamps agree deterministically across shards — the FAA of
the paper becomes a replicated counter.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core import store as S
from repro.core.ref import KEY_MAX, NOT_FOUND, OP_NOP, OP_RANGE


@dataclasses.dataclass(frozen=True)
class ShardedConfig:
    base: S.UruvConfig
    key_lo: int = 0
    key_hi: int = 1 << 30
    axis_name: str = "data"

    def span(self, n_shards: int) -> int:
        return -(-(self.key_hi - self.key_lo) // n_shards)


def create(cfg: ShardedConfig, mesh: Mesh) -> S.UruvStore:
    """A stacked store: every array gains a leading [n_shards] axis."""
    n = mesh.shape[cfg.axis_name]
    proto = S.create(cfg.base)

    def stack(x):
        return jnp.broadcast_to(x, (n,) + x.shape)

    stacked = jax.tree.map(stack, proto)
    sharding = NamedSharding(mesh, P(cfg.axis_name))
    return jax.device_put(stacked, sharding)


def _owner(cfg: ShardedConfig, keys: jax.Array, n_shards: int) -> jax.Array:
    span = cfg.span(n_shards)
    return jnp.clip((keys - cfg.key_lo) // span, 0, n_shards - 1).astype(jnp.int32)


def _mixed_core(cfg: ShardedConfig, n_shards: int, st, codes, keys, values,
                light_path: bool = True):
    """Shared SPMD body: apply the replicated mixed announce on one shard.

    Ops not owned by this shard become NOPs; per-op global timestamps keep
    the announce-order linearization exact across shards.
    """
    ax = cfg.axis_name
    i32 = jnp.int32
    G = keys.shape[0]
    me = lax.axis_index(ax)
    mine = (_owner(cfg, keys, n_shards) == me) & (keys < KEY_MAX)
    lcodes = jnp.where(mine, codes, OP_NOP)
    lkeys = jnp.where(mine, keys, KEY_MAX)
    base = st.ts
    new_store, res, ok = S.bulk_apply(
        st, lcodes, lkeys, values,
        op_ts=base + jnp.arange(G, dtype=i32),
        next_ts=base + jnp.asarray(G, i32),
        light_path=light_path,
    )
    res_all = lax.psum(jnp.where(mine, res - NOT_FOUND, 0), ax) + NOT_FOUND
    ok_all = lax.psum(jnp.where(ok, 0, 1), ax) == 0
    return new_store, res_all, ok_all


def make_apply(cfg: ShardedConfig, mesh: Mesh, *, light_path: bool = True):
    """Jitted SPMD mixed-op pass over a *replicated* announce array.

    (store, op_codes[G], keys[G], values[G]) -> (store, results[G], ok).

    On ok=False the returned store is cross-shard INCONSISTENT (shards that
    individually succeeded applied their ops and advanced their clocks;
    the rejecting shard did not) — callers MUST discard it and retry from
    the input store, e.g. via :func:`sharded_apply_batch`.  The same
    contract applies to the ``update`` op of :func:`make_ops`.
    """
    ax = cfg.axis_name
    n_shards = mesh.shape[ax]

    def _apply_block(st_blk, codes, keys, values):
        st = jax.tree.map(lambda x: x[0], st_blk)
        new_store, res_all, ok = _mixed_core(cfg, n_shards, st, codes, keys,
                                             values, light_path)
        return jax.tree.map(lambda x: x[None], new_store), res_all, ok

    return jax.jit(
        shard_map(
            _apply_block,
            mesh=mesh,
            in_specs=(P(ax), P(None), P(None), P(None)),
            out_specs=(P(ax), P(), P()),
        )
    )


def make_routed_apply(cfg: ShardedConfig, mesh: Mesh, *,
                      route_factor: int = 2, light_path: bool = True):
    """Jitted SPMD mixed-op pass over a *sharded* announce array.

    The announce arrays arrive partitioned over ``axis_name`` (global width
    G must be a multiple of the shard count; see :func:`pad_announce`).
    Each shard packs its slice's ops by owner into a [n_shards, cap] staging
    buffer (cap = ceil(W * route_factor / n_shards)) and an all_to_all ships
    them; owners apply their routed subset with ``op_ts = base + global
    announce position`` — the timestamp plumbing that makes the routed
    linearization identical to the replicated one.  If any shard receives
    more ops than its budget, the pass returns ok=False with the input
    store's ops only partially applied — callers MUST discard the returned
    store on ok=False and retry via the replicated pass (functional updates
    make that free).
    """
    ax = cfg.axis_name
    n_shards = mesh.shape[ax]

    def _routed_block(st_blk, codes, keys, values):
        st = jax.tree.map(lambda x: x[0], st_blk)
        i32 = jnp.int32
        W = keys.shape[0]                    # local announce slice
        G = W * n_shards
        cap = max(1, -(-(W * route_factor) // n_shards))
        me = lax.axis_index(ax)
        pos = me * W + jnp.arange(W, dtype=i32)   # global announce positions

        route = (keys < KEY_MAX) & (codes != OP_NOP)
        owner = jnp.where(route, _owner(cfg, keys, n_shards), n_shards)
        onehot = (owner[:, None] == jnp.arange(n_shards, dtype=i32)[None, :])
        rank = jnp.take_along_axis(
            jnp.cumsum(onehot.astype(i32), axis=0),
            jnp.minimum(owner, n_shards - 1)[:, None], axis=1,
        )[:, 0] - 1
        lost = jnp.any((owner < n_shards) & (rank >= cap))
        row = jnp.where((owner < n_shards) & (rank < cap), owner, n_shards)
        col = jnp.minimum(rank, cap - 1)
        stage = lambda fill, x: jnp.full((n_shards, cap), fill, i32).at[
            row, col].set(x, mode="drop")
        send = (stage(OP_NOP, codes), stage(KEY_MAX, keys),
                stage(0, values), stage(0, pos))
        rcodes, rkeys, rvals, rpos = (
            lax.all_to_all(x, ax, split_axis=0, concat_axis=0) for x in send
        )
        # flatten: row s came from source shard s, whose positions are
        # [s*W, (s+1)*W) packed in order -> valid ops stay globally
        # announce-ordered, which bulk_apply's op_ts contract requires.
        flat_codes = rcodes.reshape(-1)
        flat_keys = rkeys.reshape(-1)
        flat_pos = rpos.reshape(-1)
        base = st.ts
        new_store, res, ok = S.bulk_apply(
            st, flat_codes, flat_keys, rvals.reshape(-1),
            op_ts=base + flat_pos,
            next_ts=base + jnp.asarray(G, i32),
            light_path=light_path,
        )
        contrib = jnp.zeros((G,), i32).at[flat_pos].add(
            jnp.where(flat_keys < KEY_MAX, res - NOT_FOUND, 0)
        )
        res_all = lax.psum(contrib, ax) + NOT_FOUND
        ok_all = lax.psum(jnp.where(ok & ~lost, 0, 1), ax) == 0
        return jax.tree.map(lambda x: x[None], new_store), res_all, ok_all

    return jax.jit(
        shard_map(
            _routed_block,
            mesh=mesh,
            in_specs=(P(ax), P(ax), P(ax), P(ax)),
            out_specs=(P(ax), P(), P()),
        )
    )


def make_range_apply(cfg: ShardedConfig, mesh: Mesh, *,
                     max_results: int = 1024, scan_leaves: int = 16,
                     max_rounds: int = 8):
    """Jitted SPMD batched range search over a *replicated* query array.

    (store, k1[Q], k2[Q], snap_ts[Q]) ->
        (keys[Q, max_results], values[Q, max_results], count[Q],
         truncated[Q], resume_k1[Q])

    Every shard answers all Q intervals against its OWN leaves in one
    `store.bulk_range` pass (a shard only holds keys it owns, so the scan
    is naturally the local intersection of [k1, k2]); the per-shard blocks
    are all_gather'ed and merged by key on device.  Because shards share
    the replicated global clock and per-op timestamps (DESIGN.md Sec 3),
    the merged rows — values AND their snapshot resolution — are
    bit-identical to single-device `bulk_range` whenever neither side
    budget-truncates (and on `max_results` overflow, which caps both
    identically).  Note the leaf budget is pooled PER SHARD: the sharded
    aggregate is n_shards x the single-device pool, so a scan that
    exhausts the single-device budget may complete here — size budgets
    for the per-shard window when exact truncation parity matters.

    Exactness under truncation: a shard that truncated has only covered
    keys below its ``resume_k1``, so the merge clamps to the minimum
    truncated-shard resume point (the frontier) before taking the
    max_results smallest keys; ``resume_k1`` of the merged result lets the
    host paginate exactly as in the single-device contract.
    """
    ax = cfg.axis_name
    n_shards = mesh.shape[ax]
    R = max_results

    def _range_block(st_blk, k1, k2, snap):
        st = jax.tree.map(lambda x: x[0], st_blk)
        i32 = jnp.int32
        Q = k1.shape[0]
        keys, vals, _, trunc, resume = S.bulk_range(
            st, k1, k2, snap,
            max_results=R, scan_leaves=scan_leaves, max_rounds=max_rounds,
        )
        allk = lax.all_gather(keys, ax)                    # [n, Q, R]
        allv = lax.all_gather(vals, ax)
        allt = lax.all_gather(trunc, ax)                   # [n, Q]
        allr = lax.all_gather(resume, ax)
        ceil = jnp.min(jnp.where(allt, allr, KEY_MAX), axis=0)      # [Q]
        mk = jnp.moveaxis(allk, 0, 1).reshape(Q, n_shards * R)
        mv = jnp.moveaxis(allv, 0, 1).reshape(Q, n_shards * R)
        keep = mk < ceil[:, None]          # drops padding AND beyond-frontier
        mk = jnp.where(keep, mk, KEY_MAX)
        mv = jnp.where(keep, mv, NOT_FOUND)
        sk, sv = lax.sort((mk, mv), dimension=1, num_keys=1)
        total = jnp.sum(keep.astype(i32), axis=1)
        count = jnp.minimum(total, R)
        out_keys, out_vals = sk[:, :R], sv[:, :R]
        overflow = total > R
        trunc_g = overflow | (ceil < KEY_MAX)
        last = jnp.take_along_axis(
            out_keys, jnp.maximum(count - 1, 0)[:, None], axis=1
        )[:, 0]
        resume_g = jnp.where(
            overflow, last + 1, jnp.where(ceil < KEY_MAX, ceil, k2)
        )
        return out_keys, out_vals, count, trunc_g, resume_g

    return jax.jit(
        shard_map(
            _range_block,
            mesh=mesh,
            in_specs=(P(ax), P(None), P(None), P(None)),
            out_specs=(P(), P(), P(), P(), P()),
        )
    )


def pad_announce(codes, keys, values, multiple: int):
    """Pad a host announce array with NOPs to a width multiple (routing)."""
    codes = np.asarray(codes, np.int32)
    keys = np.asarray(keys, np.int32)
    values = np.asarray(values, np.int32)
    r = (-len(keys)) % multiple
    if r:
        codes = np.concatenate([codes, np.full(r, OP_NOP, np.int32)])
        keys = np.concatenate([keys, np.full(r, KEY_MAX, np.int32)])
        values = np.concatenate([values, np.zeros(r, np.int32)])
    return codes, keys, values


def sharded_apply_batch(store, codes, keys, values, *, apply_fn,
                        routed_fn=None, stats=None):
    """Host fast/slow sequencing: routed pass first, replicated fallback.

    Returns (store, results[G]).  Raises RuntimeError if even the
    replicated pass rejects (capacity); the error carries the OR of the
    rejecting shards' fresh ``OFLOW_*`` bits as ``.oflow_reason`` so the
    caller's lifecycle policy (grow / maintain / compact + retry — see
    ``repro.api.ShardedExecutor``) can relieve the right pool, mirroring
    repro.core.batch.  CRUD codes only: the SPMD passes
    are built on `store.bulk_apply`, which treats OP_RANGE as NOP — range
    announce arrays go through :func:`make_range_apply` instead, so reject
    them loudly here rather than silently returning NOT_FOUND.
    """
    if np.any(np.asarray(codes) == OP_RANGE):
        raise ValueError(
            "sharded_apply_batch handles SEARCH/INSERT/DELETE/NOP only; "
            "answer OP_RANGE announce arrays via make_range_apply"
        )
    from repro.core.batch import _bump   # shared stats counter (host-side)

    if routed_fn is not None:
        _bump(stats, "device_passes")
        new_store, res, ok = routed_fn(
            store, jnp.asarray(codes), jnp.asarray(keys), jnp.asarray(values)
        )
        if bool(ok):
            return new_store, np.asarray(res)
        # routing budget exceeded: discard the partial store, fall back
    _bump(stats, "device_passes")
    new_store, res, ok = apply_fn(
        store, jnp.asarray(codes), jnp.asarray(keys), jnp.asarray(values)
    )
    if not bool(ok):
        reason = int(np.bitwise_or.reduce(
            np.asarray(new_store.oflow).reshape(-1))) & ~int(
            np.bitwise_or.reduce(np.asarray(store.oflow).reshape(-1)))
        err = RuntimeError(
            "sharded announce rejected by every shard path (capacity); "
            "grow/compact or widen the shard stores"
        )
        err.oflow_reason = reason
        raise err
    return new_store, np.asarray(res)


def make_ops(cfg: ShardedConfig, mesh: Mesh):
    """Build jitted SPMD (update, lookup, range) ops for a given mesh.

    ``update`` shares :func:`make_apply`'s rejection contract: on ok=False
    the returned store is cross-shard inconsistent and must be discarded
    (retry from the input store; functional updates make that free).
    """
    ax = cfg.axis_name
    n_shards = mesh.shape[ax]
    store_specs = P(ax)

    # Each shard's block carries a leading [1] axis under shard_map.
    def _upd_block(st_blk, keys, values):
        st = jax.tree.map(lambda x: x[0], st_blk)
        codes = S.derive_update_codes(keys, values)
        new_store, prev_all, ok = _mixed_core(cfg, n_shards, st, codes, keys, values)
        return jax.tree.map(lambda x: x[None], new_store), prev_all, ok

    update = jax.jit(
        shard_map(
            _upd_block,
            mesh=mesh,
            in_specs=(store_specs, P(None), P(None)),
            out_specs=(store_specs, P(), P()),
        )
    )

    def _lkp_block(st_blk, keys, snap):
        st = jax.tree.map(lambda x: x[0], st_blk)
        me = lax.axis_index(ax)
        mine = _owner(cfg, keys, n_shards) == me
        k = jnp.where(mine & (keys < KEY_MAX), keys, KEY_MAX)
        vals = S.bulk_lookup(st, k, snap)
        return lax.psum(jnp.where(mine, vals - NOT_FOUND, 0), ax) + NOT_FOUND

    lookup = jax.jit(
        shard_map(
            _lkp_block,
            mesh=mesh,
            in_specs=(store_specs, P(None), P()),
            out_specs=P(),
        )
    )

    def _rq_block(st_blk, k1, k2, snap, max_scan_leaves, max_results):
        st = jax.tree.map(lambda x: x[0], st_blk)
        keys, vals, cnt, trunc = S.range_query(
            st, k1[0], k2[0], snap[0],
            max_scan_leaves=max_scan_leaves, max_results=max_results,
        )
        return keys[None], vals[None], cnt[None], trunc[None]

    @functools.partial(jax.jit, static_argnames=("max_scan_leaves", "max_results"))
    def range_q(store, k1, k2, snap, *, max_scan_leaves=64, max_results=1024):
        f = shard_map(
            functools.partial(
                _rq_block,
                max_scan_leaves=max_scan_leaves,
                max_results=max_results,
            ),
            mesh=mesh,
            in_specs=(store_specs, P(None), P(None), P(None)),
            out_specs=(P(ax), P(ax), P(ax), P(ax)),
        )
        k1a = jnp.broadcast_to(jnp.asarray(k1, jnp.int32), (1,))
        k2a = jnp.broadcast_to(jnp.asarray(k2, jnp.int32), (1,))
        sa = jnp.broadcast_to(jnp.asarray(snap, jnp.int32), (1,))
        return f(store, k1a, k2a, sa)

    return update, lookup, range_q


def merge_range_results(keys, vals, counts) -> list:
    """Host-side merge of per-shard range results (shards are key-ordered)."""
    out = []
    keys = np.asarray(keys)
    vals = np.asarray(vals)
    counts = np.asarray(counts)
    for s in range(keys.shape[0]):
        c = int(counts[s])
        out.extend(zip(keys[s, :c].tolist(), vals[s, :c].tolist()))
    return out


def global_ts(store) -> int:
    """The replicated FAA counter (identical on every shard)."""
    return int(np.asarray(store.ts)[0])


def sharded_snapshot(store):
    """Register a snapshot on every shard (replicated tracker)."""
    snap = global_ts(store)
    new = jax.vmap(lambda st: S.snapshot(st)[0])(store)
    return new, snap


def sharded_release(store, snap: int):
    return jax.vmap(lambda st: S.release(st, jnp.asarray(snap, jnp.int32)))(store)
