"""ShardedUruv — key-range-partitioned Uruv over a mesh axis (shard_map).

Scaling the paper's store across chips: the key space is range-partitioned;
every device owns one UruvStore shard (all store arrays carry a leading
device axis, sharded over ``axis_name``).  Bulk ADT calls are SPMD programs:

  update:  all_gather the announce array -> each shard filters + applies its
           own keys locally (one bounded pass, same wait-free argument).
  lookup:  all_gather -> owner answers -> psum-combine (one-hot by ownership).
  range :  every shard scans its local intersection of [k1,k2]; results are
           all_gather'ed and host-merged.

The global clock stays consistent without communication: every shard
advances its local ts by the (identical) announce width per batch, so
timestamps agree deterministically across shards — the FAA of the paper
becomes a replicated counter.

The replicated announce distribution is the paper-faithful design ("every
thread reads the whole stateArray"): each shard scans the full announce
array and applies its own keys.  A ragged all_to_all routing variant
(collective bytes O(G) instead of O(G·devices)) is the documented next
step in EXPERIMENTS.md §Perf; it requires per-op timestamp plumbing through
``bulk_update`` to preserve announce-order linearization.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import store as S
from repro.core.ref import KEY_MAX, NOT_FOUND


@dataclasses.dataclass(frozen=True)
class ShardedConfig:
    base: S.UruvConfig
    key_lo: int = 0
    key_hi: int = 1 << 30
    axis_name: str = "data"

    def span(self, n_shards: int) -> int:
        return -(-(self.key_hi - self.key_lo) // n_shards)


def create(cfg: ShardedConfig, mesh: Mesh) -> S.UruvStore:
    """A stacked store: every array gains a leading [n_shards] axis."""
    n = mesh.shape[cfg.axis_name]
    proto = S.create(cfg.base)

    def stack(x):
        return jnp.broadcast_to(x, (n,) + x.shape)

    stacked = jax.tree.map(stack, proto)
    sharding = NamedSharding(mesh, P(cfg.axis_name))
    return jax.device_put(stacked, sharding)


def _owner(cfg: ShardedConfig, keys: jax.Array, n_shards: int) -> jax.Array:
    span = cfg.span(n_shards)
    return jnp.clip((keys - cfg.key_lo) // span, 0, n_shards - 1).astype(jnp.int32)


def make_ops(cfg: ShardedConfig, mesh: Mesh):
    """Build jitted SPMD (update, lookup, range) ops for a given mesh."""
    ax = cfg.axis_name
    n_shards = mesh.shape[ax]
    store_specs = P(ax)

    def _local_update(store, keys, values):
        me = lax.axis_index(ax)
        mine = _owner(cfg, keys, n_shards) == me
        k = jnp.where(mine & (keys < KEY_MAX), keys, KEY_MAX)
        v = jnp.where(mine, values, 0)
        new_store, prev, ok = S.bulk_update(store, k, v)
        # combine per-op results: owner contributes, others contribute 0
        prev_all = lax.psum(jnp.where(mine, prev - NOT_FOUND, 0), ax) + NOT_FOUND
        return new_store, prev_all, lax.psum(jnp.where(ok, 0, 1), ax) == 0

    # Each shard's block carries a leading [1] axis under shard_map.
    def _upd_block(st_blk, keys, values):
        st = jax.tree.map(lambda x: x[0], st_blk)
        new_store, prev_all, ok = _local_update(st, keys, values)
        return jax.tree.map(lambda x: x[None], new_store), prev_all, ok

    update = jax.jit(
        jax.shard_map(
            _upd_block,
            mesh=mesh,
            in_specs=(store_specs, P(None), P(None)),
            out_specs=(store_specs, P(), P()),
        )
    )

    def _lkp_block(st_blk, keys, snap):
        st = jax.tree.map(lambda x: x[0], st_blk)
        me = lax.axis_index(ax)
        mine = _owner(cfg, keys, n_shards) == me
        k = jnp.where(mine & (keys < KEY_MAX), keys, KEY_MAX)
        vals = S.bulk_lookup(st, k, snap)
        return lax.psum(jnp.where(mine, vals - NOT_FOUND, 0), ax) + NOT_FOUND

    lookup = jax.jit(
        jax.shard_map(
            _lkp_block,
            mesh=mesh,
            in_specs=(store_specs, P(None), P()),
            out_specs=P(),
        )
    )

    def _rq_block(st_blk, k1, k2, snap, max_scan_leaves, max_results):
        st = jax.tree.map(lambda x: x[0], st_blk)
        keys, vals, cnt, trunc = S.range_query(
            st, k1[0], k2[0], snap[0],
            max_scan_leaves=max_scan_leaves, max_results=max_results,
        )
        return keys[None], vals[None], cnt[None], trunc[None]

    @functools.partial(jax.jit, static_argnames=("max_scan_leaves", "max_results"))
    def range_q(store, k1, k2, snap, *, max_scan_leaves=64, max_results=1024):
        f = jax.shard_map(
            functools.partial(
                _rq_block,
                max_scan_leaves=max_scan_leaves,
                max_results=max_results,
            ),
            mesh=mesh,
            in_specs=(store_specs, P(None), P(None), P(None)),
            out_specs=(P(ax), P(ax), P(ax), P(ax)),
        )
        k1a = jnp.broadcast_to(jnp.asarray(k1, jnp.int32), (1,))
        k2a = jnp.broadcast_to(jnp.asarray(k2, jnp.int32), (1,))
        sa = jnp.broadcast_to(jnp.asarray(snap, jnp.int32), (1,))
        return f(store, k1a, k2a, sa)

    return update, lookup, range_q


def merge_range_results(keys, vals, counts) -> list:
    """Host-side merge of per-shard range results (shards are key-ordered)."""
    out = []
    keys = np.asarray(keys)
    vals = np.asarray(vals)
    counts = np.asarray(counts)
    for s in range(keys.shape[0]):
        c = int(counts[s])
        out.extend(zip(keys[s, :c].tolist(), vals[s, :c].tolist()))
    return out


def global_ts(store) -> int:
    """The replicated FAA counter (identical on every shard)."""
    return int(np.asarray(store.ts)[0])


def sharded_snapshot(store):
    """Register a snapshot on every shard (replicated tracker)."""
    snap = global_ts(store)
    new = jax.vmap(lambda st: S.snapshot(st)[0])(store)
    return new, snap


def sharded_release(store, snap: int):
    return jax.vmap(lambda st: S.release(st, jnp.asarray(snap, jnp.int32)))(store)
