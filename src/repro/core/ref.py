"""Sequential reference oracle for Uruv's ADT.

This is the ground truth the JAX/Pallas implementations are validated
against.  It implements the paper's ADT *with* MVCC semantics:

  - INSERT(K, V)       -> version node (ts, V) appended at vhead
  - DELETE(K)          -> version node (ts, TOMBSTONE) appended (paper Sec 3.2:
                          "we utilise a tombstone value ... deleting a node
                          requires no help since there is no delinking")
  - SEARCH(K)          -> latest version's value, or NOT_FOUND
  - RANGEQUERY(K1, K2) -> snapshot ts := FAA(global_ts); per key the first
                          version with ts <= snapshot (paper Sec 3.4)

Linearization of a batch ("announce array") follows announce order: op i in a
batch gets timestamp base_ts + i, matching the wait-free combining
construction in ``repro.core.batch`` (DESIGN.md Sec 2).

Plain Python / O(n) — used only by tests and benchmarks as an oracle.
"""

from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

# Sentinels (shared with the JAX store; see repro.core.store).  This is
# the ONE module allowed to spell the key-sentinel family as literals —
# everywhere else imports the names (uruvlint rule `sentinel-literal`,
# DESIGN.md Sec 13): KEY_MAX masks out / pads, KEY_MAX - 1 is the
# kernels' internal pad sentinel, and user keys end at KEY_DOMAIN_HI.
KEY_MAX = 2**31 - 1          # padding sentinel — valid keys are < KEY_MAX - 1
KEY_DOMAIN_HI = KEY_MAX - 2  # largest user-visible key (2**31 - 3)
TOMBSTONE = -(2**31) + 1     # paper's tombstone value
NOT_FOUND = -1               # paper: SEARCH returns -1 when absent

OP_INSERT = 0
OP_DELETE = 1
OP_SEARCH = 2
OP_NOP = 3
OP_RANGE = 4                 # RANGEQUERY: key = k1, value = k2; result = count


@dataclass
class _Version:
    ts: int
    value: int


@dataclass
class RefStore:
    """Sequential oracle: sorted dict of key -> descending-ts version list."""

    versions: Dict[int, List[_Version]] = field(default_factory=dict)
    ts: int = 0
    # Version tracker: active snapshot timestamps (paper Appendix E).
    active_snapshots: Dict[int, int] = field(default_factory=dict)  # ts -> refcount

    # ---- single ops (each advances the clock by 1) ------------------------
    def insert(self, key: int, value: int) -> None:
        self._append_version(key, value)

    def delete(self, key: int) -> bool:
        """Returns True iff the key was present (not already tombstoned)."""
        present = self.search(key, advance=False) != NOT_FOUND
        self._append_version(key, TOMBSTONE)
        return present

    def search(self, key: int, advance: bool = False) -> int:
        if advance:
            self.ts += 1
        chain = self.versions.get(key)
        if not chain:
            return NOT_FOUND
        v = chain[-1].value  # latest
        return NOT_FOUND if v == TOMBSTONE else v

    def search_at(self, key: int, snap_ts: int) -> int:
        """First version with ts <= snap_ts (paper's versioned read)."""
        chain = self.versions.get(key)
        if not chain:
            return NOT_FOUND
        # chain is ascending in ts; find rightmost with ts <= snap_ts
        idx = bisect.bisect_right([v.ts for v in chain], snap_ts) - 1
        if idx < 0:
            return NOT_FOUND
        v = chain[idx].value
        return NOT_FOUND if v == TOMBSTONE else v

    def snapshot(self) -> int:
        """RANGEQUERY LP: atomic read+increment of the global timestamp."""
        snap = self.ts
        self.ts += 1
        self.active_snapshots[snap] = self.active_snapshots.get(snap, 0) + 1
        return snap

    def release(self, snap_ts: int) -> None:
        c = self.active_snapshots.get(snap_ts, 0) - 1
        if c <= 0:
            self.active_snapshots.pop(snap_ts, None)
        else:
            self.active_snapshots[snap_ts] = c

    def range_query(
        self, k1: int, k2: int, snap_ts: Optional[int] = None
    ) -> List[Tuple[int, int]]:
        if snap_ts is None:
            snap_ts = self.snapshot()
            self.release(snap_ts)
        out = []
        for key in sorted(self.versions):
            if k1 <= key <= k2:
                v = self.search_at(key, snap_ts)
                if v != NOT_FOUND:
                    out.append((key, v))
        return out

    # ---- batched ops (announce-array semantics) ---------------------------
    def apply_batch(self, ops: List[Tuple[int, int, int]]) -> List[int]:
        """ops: list of (op_code, key, value). Linearized in announce order.

        Op i gets timestamp base_ts + i.  Returns per-op results:
        INSERT -> previous value (NOT_FOUND if new); DELETE -> previous value;
        SEARCH -> value; RANGE (key=k1, value=k2) -> number of live keys in
        [k1, k2] at the op's snapshot; NOP -> NOT_FOUND.
        """
        base = self.ts
        results = []
        for i, (op, key, value) in enumerate(ops):
            ts_i = base + i
            if op == OP_INSERT:
                results.append(self.search(key))
                self._append_version(key, value, ts=ts_i)
            elif op == OP_DELETE:
                results.append(self.search(key))
                self._append_version(key, TOMBSTONE, ts=ts_i)
            elif op == OP_SEARCH:
                results.append(self.search_at(key, ts_i))
            elif op == OP_RANGE:
                results.append(len(self.range_query(key, value, ts_i)))
            else:
                results.append(NOT_FOUND)
        self.ts = base + len(ops)
        return results

    # ---- GC (paper Appendix E: version tracker gated reclamation) ---------
    def min_active_ts(self) -> int:
        return min(self.active_snapshots, default=self.ts)

    def compact(self) -> int:
        """Physically drop versions unreachable by any active snapshot.

        A version is reclaimable if a newer version of the same key also has
        ts <= min_active_ts.  Fully-tombstoned keys older than every active
        snapshot are removed.  Returns number of versions reclaimed.
        """
        floor = self.min_active_ts()
        reclaimed = 0
        for key in list(self.versions):
            chain = self.versions[key]
            keep_from = 0
            for j in range(len(chain) - 1):
                if chain[j + 1].ts <= floor:
                    keep_from = j + 1
            reclaimed += keep_from
            chain = chain[keep_from:]
            if len(chain) == 1 and chain[0].value == TOMBSTONE and chain[0].ts <= floor:
                reclaimed += 1
                del self.versions[key]
            else:
                self.versions[key] = chain
        return reclaimed

    # ---- internals ---------------------------------------------------------
    def _append_version(self, key: int, value: int, ts: Optional[int] = None) -> None:
        if ts is None:
            ts = self.ts
            self.ts += 1
        self.versions.setdefault(key, []).append(_Version(ts, value))

    # ---- introspection for tests -------------------------------------------
    def live_items(self) -> List[Tuple[int, int]]:
        out = []
        for key in sorted(self.versions):
            v = self.search(key)
            if v != NOT_FOUND:
                out.append((key, v))
        return out

    def num_versions(self) -> int:
        return sum(len(c) for c in self.versions.values())
