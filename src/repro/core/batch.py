"""Wait-free combining layer — the announce/help construction, batched.

The paper's fast-path/slow-path (Kogan–Petrank [16], Sec 4) maps to:

  * fast path  — the whole *mixed* announce array (SEARCH / INSERT /
    DELETE / NOP) is applied in ONE deterministic data-parallel pass
    (`store.bulk_apply`): updates append versions at their per-op
    timestamps and searches resolve at their per-op snapshots, all in the
    same device call.  This succeeds unless the batch over-concentrates
    structural inserts (> L new keys into one leaf) or a pool fills up.
  * slow path  — on rejection the combining layer *helps in rounds*: it
    halves the announce array and re-applies with the ORIGINAL per-op
    timestamps (`op_ts` plumbing), so the linearization is bit-identical
    to the one-pass application; capacity overflows trigger `compact()`
    (the GC the paper performs during split/merge, gated by the version
    tracker).  Recursion terminates: a single op can never violate the
    per-leaf bound, so every op completes in a bounded number of rounds —
    wait-freedom.

This module is host-side control flow around jitted kernels (the usual
launcher/runtime split in a TPU system: device passes are bounded and
deterministic, the host sequences them).
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax.numpy as jnp

from repro.core import lifecycle as LC
from repro.core import store as S
from repro.core.ref import KEY_MAX, NOT_FOUND, OP_RANGE


class CapacityError(RuntimeError):
    """The store cannot fit the working set under the active policy.

    With the default self-sizing lifecycle (``LifecyclePolicy.auto_grow``)
    this is no longer a steady-state condition — it is raised only when
    growth is disabled, a single op violates ``leaf_cap``, or the bounded
    retry loops fail to converge.  Carries diagnostics:

      * ``oflow``      — the ``OFLOW_*`` bitmask of the last rejection
      * ``occupancy``  — leaf-allocator occupancy ``n_alloc / max_leaves``
      * ``frozen_fraction`` — dead (unreferenced-but-allocated) fraction
      * ``n_vers`` / ``max_versions`` — version-pool fill
    """

    def __init__(self, message: str, *, store: Optional[S.UruvStore] = None,
                 oflow: int = 0):
        self.oflow = int(oflow)
        self.occupancy = 0.0
        self.frozen_fraction = 0.0
        self.n_vers = 0
        self.max_versions = 0
        if store is not None:
            n_alloc = int(np.asarray(store.n_alloc).sum())
            self.occupancy = n_alloc / max(
                int(store.cfg.max_leaves) * np.asarray(store.ts).size, 1
            )
            self.frozen_fraction = LC.dead_fraction(store)
            self.n_vers = int(np.asarray(store.n_vers).max())
            self.max_versions = int(store.cfg.max_versions)
            message = (
                f"{message} [oflow={self.oflow:#x} "
                f"occupancy={self.occupancy:.2f} "
                f"frozen_fraction={self.frozen_fraction:.2f} "
                f"versions={self.n_vers}/{self.max_versions}]"
            )
        super().__init__(message)


MAX_SLOWPATH_ROUNDS = 64


def _clear_oflow(store: S.UruvStore) -> S.UruvStore:
    return dataclasses.replace(store, oflow=jnp.zeros_like(store.oflow))


def _bump(stats: Optional[Dict[str, int]], key: str, by: int = 1) -> None:
    if stats is not None:
        stats[key] = stats.get(key, 0) + by


def _apply_rounds(
    store: S.UruvStore,
    codes: np.ndarray,
    keys: np.ndarray,
    values: np.ndarray,
    op_ts: Optional[np.ndarray],
    next_ts,
    *,
    light_path: bool = True,
    backend: Optional[str] = None,
    stats: Optional[Dict[str, int]] = None,
    policy: Optional[LC.LifecyclePolicy] = None,
    _depth: int = 0,
) -> Tuple[S.UruvStore, np.ndarray]:
    """One fast-path attempt + bounded help-rounds on rejection.

    ``op_ts is None`` is the common entry: the device pass assigns
    ``store.ts + i`` itself (zero host syncs on the fast path).  Slow-path
    recursion materialises the timestamps once and slices them, so every
    round applies its ops at exactly the timestamps the one-pass
    application would have used.  ``stats`` (see ``repro.api``) counts
    every device pass and slow-path round.

    Capacity policy (DESIGN.md Sec 10): with a ``policy`` whose
    ``auto_grow`` is set (the ``repro.api`` default), ``OFLOW_LEAVES`` /
    ``OFLOW_VERSIONS`` rejections run one ``lifecycle.relieve_pressure``
    step (incremental maintain, pool doubling, or tracker-gated compact)
    and retry — no steady-state ``CapacityError``.  ``policy=None`` keeps
    the legacy fixed-footprint behaviour: compact-then-retry, error when
    compaction frees nothing.  Lifecycle choices never alter results or
    timestamps, only where the arrays live.
    """
    if _depth > MAX_SLOWPATH_ROUNDS:
        raise CapacityError("slow path failed to converge; store too small",
                            store=store)
    _bump(stats, "device_passes")
    new_store, res, ok = S.bulk_apply(
        store, codes, keys, values, op_ts=op_ts, next_ts=next_ts,
        light_path=light_path, backend=backend,
    )
    if bool(ok):
        return new_store, np.asarray(res)
    _bump(stats, "slow_path_rounds")
    reason = int(new_store.oflow) & ~int(store.oflow)
    if reason & S.OFLOW_INDEX:
        # fat-node pools fragmented (or root overflow): reindex repacks
        # them at pack_fill — no capacity growth, results unchanged —
        # then retry at the SAME timestamps.  Available under every
        # policy (it is reclamation, not growth).
        _bump(stats, "reindexes")
        return _apply_rounds(S.reindex(_clear_oflow(store)), codes, keys,
                             values, op_ts, next_ts, light_path=light_path,
                             backend=backend, stats=stats, policy=policy,
                             _depth=_depth + 1)
    if reason & (S.OFLOW_VERSIONS | S.OFLOW_LEAVES):
        if policy is not None and policy.auto_grow:
            relieved = LC.relieve_pressure(
                _clear_oflow(store), reason, len(keys), policy, stats=stats,
            )
            return _apply_rounds(relieved, codes, keys, values, op_ts,
                                 next_ts, light_path=light_path,
                                 backend=backend, stats=stats, policy=policy,
                                 _depth=_depth + 1)
        _bump(stats, "compactions")
        compacted, _ = S.compact(_clear_oflow(store))
        # progress check on the actual constrained resources: the version
        # pool and the leaf bump-allocator (compact() resets both)
        progressed = (
            int(compacted.n_vers) < int(store.n_vers)
            or int(compacted.n_alloc) < int(store.n_alloc)
        )
        if not progressed and not (reason & S.OFLOW_LEAFBATCH):
            raise CapacityError(
                f"store full (versions={int(store.n_vers)}/"
                f"{store.cfg.max_versions}, "
                f"leaves={int(store.n_alloc)}/{store.cfg.max_leaves})",
                store=store, oflow=reason,
            )
        return _apply_rounds(compacted, codes, keys, values, op_ts, next_ts,
                             light_path=light_path, backend=backend,
                             stats=stats, policy=policy, _depth=_depth + 1)
    # OFLOW_LEAFBATCH: help in rounds — halve the announce array, keeping
    # the per-op timestamp assignment of the rejected one-pass attempt.
    if len(keys) == 1:
        raise CapacityError("single op rejected; leaf_cap too small",
                            store=store, oflow=reason)
    if op_ts is None:
        base = int(store.ts)
        op_ts = (base + np.arange(len(keys))).astype(np.int32)
        if next_ts is None:
            next_ts = base + len(keys)
    mid = len(keys) // 2
    st = _clear_oflow(store)
    st, res_a = _apply_rounds(st, codes[:mid], keys[:mid], values[:mid],
                              op_ts[:mid], int(op_ts[mid]),
                              light_path=light_path, backend=backend,
                              stats=stats, policy=policy, _depth=_depth + 1)
    st, res_b = _apply_rounds(st, codes[mid:], keys[mid:], values[mid:],
                              op_ts[mid:], next_ts,
                              light_path=light_path, backend=backend,
                              stats=stats, policy=policy, _depth=_depth + 1)
    return st, np.concatenate([res_a, res_b])


def apply_updates(
    store: S.UruvStore,
    keys: np.ndarray,
    values: np.ndarray,
) -> Tuple[S.UruvStore, np.ndarray]:
    """DEPRECATED — use ``repro.api.Uruv.apply(OpBatch.updates(keys, values))``.

    Legacy INSERT/DELETE announce array (DELETE == value TOMBSTONE, padded
    keys KEY_MAX are no-ops); returns (store, prev_values).  Delegates to
    the ``repro.api`` client, so results and linearization are bit-exact
    with the client path.
    """
    warnings.warn(
        "repro.core.batch.apply_updates is deprecated; use "
        "repro.api.Uruv.apply(OpBatch.updates(keys, values))",
        DeprecationWarning, stacklevel=2,
    )
    from repro import api

    client = api.Uruv.from_store(store)
    res = client.apply(api.OpBatch.updates(keys, values))
    return client.store, np.asarray(res.values)


def apply_mixed(
    store: S.UruvStore,
    codes: np.ndarray,
    keys: np.ndarray,
    values: np.ndarray,
    *,
    light_path: bool = True,
    backend: Optional[str] = None,
    max_results: int = 1024,
    scan_leaves: int = 16,
    max_rounds: int = 8,
    stats: Optional[Dict[str, int]] = None,
    policy: Optional[LC.LifecyclePolicy] = None,
    crud_fn=None,
    range_all_fn=None,
    get_ts_fn=None,
    set_ts_fn=None,
) -> Tuple[S.UruvStore, np.ndarray, List[Tuple[int, List[Tuple[int, int]]]]]:
    """Array-level mixed announce sequencer — the host half of the ADT.

    Linearizes ``(codes[i], keys[i], values[i])`` in announce order (op i
    at ts base+i), matching ``RefStore.apply_batch``.  Returns
    ``(store, results[n] int64, range_pages)`` where ``range_pages`` is a
    list of (announce_pos, complete (key, value) page) for every RANGE op
    (``results`` carries their live-key counts).

    Fast path: one device pass (`store.bulk_apply`) for a pure-CRUD array
    (zero host syncs).  With range ops, the array executes in segments at
    range boundaries: each CRUD run is one `bulk_apply` at its original
    announce timestamps and each run of consecutive range ops is ONE
    batched `store.bulk_range` pass against the store state that precedes
    it — so a range snapshot resolves every key at chain depth 0 and stays
    exact no matter how many same-key updates FOLLOW it in the batch
    (resolving post-hoc would walk those later versions and silently lose
    keys past cfg.max_chain; the segment order is the range analogue of
    the in-pass predecessor short-circuit that makes SEARCH exact,
    DESIGN.md Sec 3/8).

    The four hooks let another topology reuse THIS loop (one copy of the
    segmentation semantics, mirroring bulk_range_all's ``page_fn``):
    ``crud_fn(store, codes, keys, values, op_ts, next_ts)`` applies one
    CRUD segment (default: the local help-rounds; a custom fn may ignore
    ``op_ts`` if its passes derive timestamps from the store clock),
    ``range_all_fn(store, k1, k2, snaps)`` answers one RANGE segment
    completely, ``get_ts_fn(store)`` reads the global clock, and
    ``set_ts_fn(store, ts)`` restates it after a RANGE segment (range ops
    occupy announce slots but their passes do not advance the clock).
    """
    codes = np.asarray(codes, np.int32)
    keys = np.asarray(keys, np.int32)
    vals = np.asarray(values, np.int32)
    if crud_fn is None:
        def crud_fn(st, c, k, v, op_ts, next_ts):
            return _apply_rounds(st, c, k, v, op_ts, next_ts,
                                 light_path=light_path, backend=backend,
                                 stats=stats, policy=policy)
    if range_all_fn is None:
        def range_all_fn(st, k1, k2, snaps):
            return bulk_range_all(
                st, k1, k2, snaps,
                max_results=max_results, scan_leaves=scan_leaves,
                max_rounds=max_rounds, backend=backend, stats=stats,
            )
    if get_ts_fn is None:
        get_ts_fn = lambda st: int(st.ts)  # noqa: E731
    if set_ts_fn is None:
        def set_ts_fn(st, ts):
            return dataclasses.replace(st, ts=jnp.asarray(ts, jnp.int32))
    n = len(codes)
    if n == 0:
        return store, np.zeros(0, np.int64), []
    rmask = codes == OP_RANGE
    if not rmask.any():
        store, res = crud_fn(store, codes, keys, vals, None, None)
        return store, np.asarray(res).astype(np.int64), []
    base = get_ts_fn(store)
    op_ts = (base + np.arange(n)).astype(np.int32)
    results = np.full(n, NOT_FOUND, np.int64)
    range_pages: List[Tuple[int, List[Tuple[int, int]]]] = []
    i = 0
    while i < n:
        j = i
        while j < n and bool(rmask[j]) == bool(rmask[i]):
            j += 1
        if rmask[i]:
            pages = range_all_fn(store, keys[i:j], vals[i:j], op_ts[i:j])
            results[i:j] = [len(p) for p in pages]
            range_pages.extend(zip(range(i, j), pages))
            # CRUD passes advance the clock themselves (next_ts / the
            # replicated counter); range segments must restate it
            store = set_ts_fn(store, base + j)
        else:
            store, res = crud_fn(store, codes[i:j], keys[i:j], vals[i:j],
                                 op_ts[i:j], base + j)
            results[i:j] = res
        i = j
    return store, results, range_pages


def apply_batch(
    store: S.UruvStore, ops: Sequence[Tuple[int, int, int]]
) -> Tuple[S.UruvStore, List[int]]:
    """Mixed announce array of (op, key, value) tuples; thin wrapper over
    :func:`apply_mixed` keeping the oracle-shaped (store, list) signature.

    RANGEQUERY rides in the same announce array: ``(OP_RANGE, k1, k2)`` at
    announce index i scans [k1, k2] at snapshot ``base + i`` — it observes
    every earlier in-batch update and none of the later ones — and its
    result is the live-key count (full pages via ``repro.api.Uruv.apply``
    or :func:`bulk_range_all`).
    """
    codes = np.array([o[0] for o in ops], np.int32)
    keys = np.array([o[1] for o in ops], np.int32)
    vals = np.array([o[2] for o in ops], np.int32)
    store, results, _ = apply_mixed(store, codes, keys, vals)
    return store, results.tolist()


# ---------------------------------------------------------------------------
# Batched range search sequencing (host side of store.bulk_range)
# ---------------------------------------------------------------------------

# sentinel interval that can never match a key (retired queries re-enter the
# device pass as no-ops: lo > every key, k2 < every key => zero work)
_DONE_LO = KEY_MAX
_DONE_HI = -(2**31)


def bulk_range_all(
    store: S.UruvStore,
    k1s,
    k2s,
    snap_ts,
    *,
    max_results: int = 1024,
    scan_leaves: int = 16,
    max_rounds: int = 8,
    backend: Optional[str] = None,
    stats: Optional[Dict[str, int]] = None,
    page_fn=None,
) -> List[List[Tuple[int, int]]]:
    """Answer Q range queries COMPLETELY; returns per-query (key, value) lists.

    One `store.bulk_range` device pass answers all Q intervals at once (the
    pooled in-pass budget covers Q * max_rounds * scan_leaves leaves,
    distributed by need); only queries still truncated after that re-enter
    the next pass, resuming from their exact ``resume_k1`` — so a giant
    scan costs O(pages) device rounds TOTAL, not O(pages) per query.  The
    active set is compacted (to power-of-two widths, bounding retraces)
    between passes, so tail pages only pay for the queries still scanning.
    Read-only: ``snap_ts`` (scalar or [Q]) must already be registered if
    isolation across later updates is required (see store.snapshot /
    release).

    ``page_fn(store, k1[W], k2[W], snap[W]) -> (keys, vals, count,
    truncated, resume_k1)`` overrides the bounded pass itself (the sharded
    executor supplies its all_gather-merged pass); the pagination loop —
    active-set compaction, resume, convergence bound — is shared either
    way, so the topologies cannot drift.
    """
    if page_fn is None:
        def page_fn(st, lo_p, hi_p, sn_p):
            _bump(stats, "device_passes")
            return S.bulk_range(
                st, lo_p, hi_p, sn_p,
                max_results=max_results, scan_leaves=scan_leaves,
                max_rounds=max_rounds, backend=backend,
            )
    k1 = np.asarray(k1s, np.int32).reshape(-1)
    k2 = np.asarray(k2s, np.int32).reshape(-1)
    Q = len(k1)
    snaps = np.broadcast_to(np.asarray(snap_ts, np.int32), (Q,))
    out: List[List[Tuple[int, int]]] = [[] for _ in range(Q)]
    idx = np.arange(Q)                    # active query -> caller position
    lo, hi, sn = k1.copy(), k2.copy(), snaps.copy()
    for _ in range(MAX_SLOWPATH_ROUNDS * 64):
        W = max(1, 1 << int(len(idx) - 1).bit_length())   # pad: bounded shapes
        pad = W - len(idx)
        lo_p = np.concatenate([lo, np.full(pad, _DONE_LO, np.int32)])
        hi_p = np.concatenate([hi, np.full(pad, _DONE_HI, np.int32)])
        sn_p = np.concatenate([sn, np.zeros(pad, np.int32)])
        keys, vals, cnt, trunc, resume = page_fn(store, lo_p, hi_p, sn_p)
        keys = np.asarray(keys)
        vals = np.asarray(vals)
        cnt = np.asarray(cnt)
        trunc = np.asarray(trunc)[: len(idx)]
        resume = np.asarray(resume)
        for a, q in enumerate(idx):
            c = int(cnt[a])
            out[q].extend(zip(keys[a, :c].tolist(), vals[a, :c].tolist()))
        if not trunc.any():
            break
        act = np.nonzero(trunc)[0]
        idx = idx[act]
        lo = resume[act].astype(np.int32)
        hi = hi[act]
        sn = sn[act]
    else:
        raise CapacityError(
            "bulk_range_all failed to converge: "
            f"{len(idx)} queries still truncated after "
            f"{MAX_SLOWPATH_ROUNDS * 64} passes; widen max_results or the "
            "scan_leaves * max_rounds leaf budget"
        )
    return out


def range_query_all(
    store: S.UruvStore,
    k1: int,
    k2: int,
    snap_ts: Optional[int] = None,
    *,
    max_scan_leaves: int = 64,
    max_results: int = 1024,
) -> Tuple[S.UruvStore, List[Tuple[int, int]]]:
    """DEPRECATED — use ``repro.api.Uruv.range(k1, k2, snap_ts)``.

    Paginated snapshot range scan covering [k1, k2] completely, with the
    legacy (store, items) signature.  Registers/releases the snapshot in
    the version tracker when ``snap_ts`` is None.  Delegates to the
    ``repro.api`` client, so pages are bit-exact with the client path.
    """
    warnings.warn(
        "repro.core.batch.range_query_all is deprecated; use "
        "repro.api.Uruv.range(k1, k2, snap_ts)",
        DeprecationWarning, stacklevel=2,
    )
    from repro import api

    client = api.Uruv.from_store(store)
    out = client.range(k1, k2, snap_ts,
                       max_results=max_results,
                       scan_leaves=max_scan_leaves,
                       max_rounds=1)
    return client.store, out
