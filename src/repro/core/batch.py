"""Wait-free combining layer — the announce/help construction, batched.

The paper's fast-path/slow-path (Kogan–Petrank [16], Sec 4) maps to:

  * fast path  — the whole announce array is applied in ONE deterministic
    data-parallel pass (`store.bulk_update`).  This succeeds unless the batch
    over-concentrates structural inserts (> L new keys into one leaf) or a
    pool fills up.
  * slow path  — on rejection the combining layer *helps in rounds*: it
    halves the announce array (preserving announce order, hence the same
    linearization) and re-applies; capacity overflows trigger `compact()`
    (the GC the paper performs during split/merge, gated by the version
    tracker).  Recursion terminates: a single op can never violate the
    per-leaf bound, so every op completes in a bounded number of rounds —
    wait-freedom.

This module is host-side control flow around jitted kernels (the usual
launcher/runtime split in a TPU system: device passes are bounded and
deterministic, the host sequences them).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import store as S
from repro.core.ref import KEY_MAX, NOT_FOUND, TOMBSTONE, OP_DELETE, OP_INSERT, OP_NOP, OP_SEARCH


class CapacityError(RuntimeError):
    """Raised when the store cannot fit the working set even after compact()."""


MAX_SLOWPATH_ROUNDS = 64


def _clear_oflow(store: S.UruvStore) -> S.UruvStore:
    return dataclasses.replace(store, oflow=jnp.zeros_like(store.oflow))


def apply_updates(
    store: S.UruvStore,
    keys: np.ndarray,
    values: np.ndarray,
    *,
    _depth: int = 0,
) -> Tuple[S.UruvStore, np.ndarray]:
    """Apply INSERT/DELETE announce array; returns (store, prev_values).

    Timestamps follow announce order across all slow-path rounds (round
    widths sum to the original width, so ts advances exactly as the
    one-pass application would).
    """
    if _depth > MAX_SLOWPATH_ROUNDS:
        raise CapacityError("slow path failed to converge; store too small")
    keys = np.asarray(keys, np.int32)
    values = np.asarray(values, np.int32)
    new_store, prev, ok = S.bulk_update(store, jnp.asarray(keys), jnp.asarray(values))
    if bool(ok):
        return new_store, np.asarray(prev)
    reason = int(new_store.oflow) & ~int(store.oflow)
    if reason & (S.OFLOW_VERSIONS | S.OFLOW_LEAVES):
        compacted, _ = S.compact(_clear_oflow(store))
        # progress check on the actual constrained resources: the version
        # pool and the leaf bump-allocator (compact() resets both)
        progressed = (
            int(compacted.n_vers) < int(store.n_vers)
            or int(compacted.n_alloc) < int(store.n_alloc)
        )
        if not progressed and not (reason & S.OFLOW_LEAFBATCH):
            raise CapacityError(
                f"store full (versions={int(store.n_vers)}/"
                f"{store.cfg.max_versions}, "
                f"leaves={int(store.n_alloc)}/{store.cfg.max_leaves})"
            )
        return apply_updates(compacted, keys, values, _depth=_depth + 1)
    # OFLOW_LEAFBATCH: help in rounds — halve the announce array.
    if len(keys) == 1:
        raise CapacityError("single op rejected; leaf_cap too small")
    mid = len(keys) // 2
    st = _clear_oflow(store)
    st, prev_a = apply_updates(st, keys[:mid], values[:mid], _depth=_depth + 1)
    st, prev_b = apply_updates(st, keys[mid:], values[mid:], _depth=_depth + 1)
    return st, np.concatenate([prev_a, prev_b])


def apply_batch(
    store: S.UruvStore, ops: Sequence[Tuple[int, int, int]]
) -> Tuple[S.UruvStore, List[int]]:
    """Mixed announce array of (op, key, value) — the full ADT, linearized
    in announce order (op i at ts base+i), matching RefStore.apply_batch.
    """
    n = len(ops)
    codes = np.array([o[0] for o in ops], np.int32)
    keys = np.array([o[1] for o in ops], np.int32)
    vals = np.array([o[2] for o in ops], np.int32)
    base = int(store.ts)

    upd_mask = (codes == OP_INSERT) | (codes == OP_DELETE)
    ukeys = np.where(upd_mask, keys, KEY_MAX).astype(np.int32)
    uvals = np.where(codes == OP_DELETE, TOMBSTONE, vals).astype(np.int32)
    store, prev = apply_updates(store, ukeys, uvals)

    results = np.full(n, NOT_FOUND, np.int64)
    results[upd_mask] = prev[upd_mask]

    search_mask = codes == OP_SEARCH
    if search_mask.any():
        skeys = np.where(search_mask, keys, KEY_MAX).astype(np.int32)
        snaps = (base + np.arange(n)).astype(np.int32)
        svals = S.bulk_lookup(store, jnp.asarray(skeys), jnp.asarray(snaps))
        results[search_mask] = np.asarray(svals)[search_mask]
    return store, results.tolist()


def range_query_all(
    store: S.UruvStore,
    k1: int,
    k2: int,
    snap_ts: Optional[int] = None,
    *,
    max_scan_leaves: int = 64,
    max_results: int = 1024,
) -> Tuple[S.UruvStore, List[Tuple[int, int]]]:
    """Paginated snapshot range scan covering [k1, k2] completely.

    Each device pass is bounded (wait-free); the host continues from the
    last key seen. Registers/releases the snapshot in the version tracker.
    """
    own_snap = snap_ts is None
    if own_snap:
        store, ts = S.snapshot(store)
        snap_ts = int(ts)
    out: List[Tuple[int, int]] = []
    lo = k1
    for _ in range(MAX_SLOWPATH_ROUNDS * 64):
        keys, vals, cnt, trunc = S.range_query(
            store, lo, k2, snap_ts,
            max_scan_leaves=max_scan_leaves, max_results=max_results,
        )
        cnt = int(cnt)
        k = np.asarray(keys)[:cnt]
        v = np.asarray(vals)[:cnt]
        out.extend(zip(k.tolist(), v.tolist()))
        if not bool(trunc):
            break
        lo = int(k[-1]) + 1 if cnt else lo + 1  # pragma: no cover (giant scans)
    if own_snap:
        store = S.release(store, snap_ts)
    return store, out
