"""Wait-free combining layer — the announce/help construction, batched.

The paper's fast-path/slow-path (Kogan–Petrank [16], Sec 4) maps to:

  * fast path  — the whole *mixed* announce array (SEARCH / INSERT /
    DELETE / NOP) is applied in ONE deterministic data-parallel pass
    (`store.bulk_apply`): updates append versions at their per-op
    timestamps and searches resolve at their per-op snapshots, all in the
    same device call.  This succeeds unless the batch over-concentrates
    structural inserts (> L new keys into one leaf) or a pool fills up.
  * slow path  — on rejection the combining layer *helps in rounds*: it
    halves the announce array and re-applies with the ORIGINAL per-op
    timestamps (`op_ts` plumbing), so the linearization is bit-identical
    to the one-pass application; capacity overflows trigger `compact()`
    (the GC the paper performs during split/merge, gated by the version
    tracker).  Recursion terminates: a single op can never violate the
    per-leaf bound, so every op completes in a bounded number of rounds —
    wait-freedom.

This module is host-side control flow around jitted kernels (the usual
launcher/runtime split in a TPU system: device passes are bounded and
deterministic, the host sequences them).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np
import jax.numpy as jnp

from repro.core import store as S


class CapacityError(RuntimeError):
    """Raised when the store cannot fit the working set even after compact()."""


MAX_SLOWPATH_ROUNDS = 64


def _clear_oflow(store: S.UruvStore) -> S.UruvStore:
    return dataclasses.replace(store, oflow=jnp.zeros_like(store.oflow))


def _apply_rounds(
    store: S.UruvStore,
    codes: np.ndarray,
    keys: np.ndarray,
    values: np.ndarray,
    op_ts: Optional[np.ndarray],
    next_ts,
    *,
    _depth: int = 0,
) -> Tuple[S.UruvStore, np.ndarray]:
    """One fast-path attempt + bounded help-rounds on rejection.

    ``op_ts is None`` is the common entry: the device pass assigns
    ``store.ts + i`` itself (zero host syncs on the fast path).  Slow-path
    recursion materialises the timestamps once and slices them, so every
    round applies its ops at exactly the timestamps the one-pass
    application would have used.
    """
    if _depth > MAX_SLOWPATH_ROUNDS:
        raise CapacityError("slow path failed to converge; store too small")
    new_store, res, ok = S.bulk_apply(
        store, codes, keys, values, op_ts=op_ts, next_ts=next_ts
    )
    if bool(ok):
        return new_store, np.asarray(res)
    reason = int(new_store.oflow) & ~int(store.oflow)
    if reason & (S.OFLOW_VERSIONS | S.OFLOW_LEAVES):
        compacted, _ = S.compact(_clear_oflow(store))
        # progress check on the actual constrained resources: the version
        # pool and the leaf bump-allocator (compact() resets both)
        progressed = (
            int(compacted.n_vers) < int(store.n_vers)
            or int(compacted.n_alloc) < int(store.n_alloc)
        )
        if not progressed and not (reason & S.OFLOW_LEAFBATCH):
            raise CapacityError(
                f"store full (versions={int(store.n_vers)}/"
                f"{store.cfg.max_versions}, "
                f"leaves={int(store.n_alloc)}/{store.cfg.max_leaves})"
            )
        return _apply_rounds(compacted, codes, keys, values, op_ts, next_ts,
                             _depth=_depth + 1)
    # OFLOW_LEAFBATCH: help in rounds — halve the announce array, keeping
    # the per-op timestamp assignment of the rejected one-pass attempt.
    if len(keys) == 1:
        raise CapacityError("single op rejected; leaf_cap too small")
    if op_ts is None:
        base = int(store.ts)
        op_ts = (base + np.arange(len(keys))).astype(np.int32)
        if next_ts is None:
            next_ts = base + len(keys)
    mid = len(keys) // 2
    st = _clear_oflow(store)
    st, res_a = _apply_rounds(st, codes[:mid], keys[:mid], values[:mid],
                              op_ts[:mid], int(op_ts[mid]), _depth=_depth + 1)
    st, res_b = _apply_rounds(st, codes[mid:], keys[mid:], values[mid:],
                              op_ts[mid:], next_ts, _depth=_depth + 1)
    return st, np.concatenate([res_a, res_b])


def apply_updates(
    store: S.UruvStore,
    keys: np.ndarray,
    values: np.ndarray,
) -> Tuple[S.UruvStore, np.ndarray]:
    """Apply INSERT/DELETE announce array; returns (store, prev_values).

    DELETE == value TOMBSTONE; padded keys (KEY_MAX) are no-ops.
    Timestamps follow announce order across all slow-path rounds (round
    widths sum to the original width, so ts advances exactly as the
    one-pass application would).
    """
    keys = np.asarray(keys, np.int32)
    values = np.asarray(values, np.int32)
    codes = np.asarray(S.derive_update_codes(keys, values))
    return _apply_rounds(store, codes, keys, values, None, None)


def apply_batch(
    store: S.UruvStore, ops: Sequence[Tuple[int, int, int]]
) -> Tuple[S.UruvStore, List[int]]:
    """Mixed announce array of (op, key, value) — the full ADT, linearized
    in announce order (op i at ts base+i), matching RefStore.apply_batch.

    Fast path: exactly one device pass (`store.bulk_apply`) for the whole
    array — searches and updates complete together, no host sync between
    them (DESIGN.md Sec 3).
    """
    codes = np.array([o[0] for o in ops], np.int32)
    keys = np.array([o[1] for o in ops], np.int32)
    vals = np.array([o[2] for o in ops], np.int32)
    store, res = _apply_rounds(store, codes, keys, vals, None, None)
    return store, res.astype(np.int64).tolist()


def range_query_all(
    store: S.UruvStore,
    k1: int,
    k2: int,
    snap_ts: Optional[int] = None,
    *,
    max_scan_leaves: int = 64,
    max_results: int = 1024,
) -> Tuple[S.UruvStore, List[Tuple[int, int]]]:
    """Paginated snapshot range scan covering [k1, k2] completely.

    Each device pass is bounded (wait-free); the host continues from the
    last key seen. Registers/releases the snapshot in the version tracker.
    """
    own_snap = snap_ts is None
    if own_snap:
        store, ts = S.snapshot(store)
        snap_ts = int(ts)
    out: List[Tuple[int, int]] = []
    lo = k1
    for _ in range(MAX_SLOWPATH_ROUNDS * 64):
        keys, vals, cnt, trunc = S.range_query(
            store, lo, k2, snap_ts,
            max_scan_leaves=max_scan_leaves, max_results=max_results,
        )
        cnt = int(cnt)
        k = np.asarray(keys)[:cnt]
        v = np.asarray(vals)[:cnt]
        out.extend(zip(k.tolist(), v.tolist()))
        if not bool(trunc):
            break
        lo = int(k[-1]) + 1 if cnt else lo + 1  # pragma: no cover (giant scans)
    if own_snap:
        store = S.release(store, snap_ts)
    return store, out
