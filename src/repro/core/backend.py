"""Backend dispatch for the Uruv hot-path primitives (DESIGN.md Sec 7).

The store's three inner loops — ``locate`` (multi-level fat-node descent
+ in-leaf rank; DESIGN.md Sec 11), ``resolve`` (versioned chain read),
and ``range_scan`` (fused leaf-window gather + versioned resolve for
batched range queries) — have three interchangeable implementations with
one contract:

  * ``xla``              — pure-jnp formulation (gather/compare-reduce
    descent via ``repro.core.index``, ``while_loop`` chain walk).  Lowers
    on every backend; the portable default off-TPU.
  * ``pallas``           — the compiled Pallas TPU kernels
    (``repro.kernels.uruv_search`` + ``repro.kernels.versioned_read`` +
    ``repro.kernels.uruv_range``).  Deployment configuration on real TPUs.
  * ``pallas_interpret`` — the same kernels under the Pallas interpreter;
    kernel-coverage testing on CPU containers.

Resolution order: :func:`set_backend` override > ``URUV_BACKEND`` env var >
auto-detect (TPU -> ``pallas``, anything else -> ``xla``).  The chosen
backend is threaded as a *static* argument through the store's jitted entry
points, so switching backends retraces rather than silently reusing a stale
compilation.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
from jax import lax

from repro.analysis.marks import device_pass
from repro.core.ref import KEY_MAX, NOT_FOUND, TOMBSTONE

XLA = "xla"
PALLAS = "pallas"
PALLAS_INTERPRET = "pallas_interpret"
BACKENDS = (XLA, PALLAS, PALLAS_INTERPRET)

ENV_VAR = "URUV_BACKEND"

_override: str | None = None


def _validate(name: str) -> str:
    if name not in BACKENDS:
        raise ValueError(f"unknown Uruv backend {name!r}; expected one of {BACKENDS}")
    return name


def set_backend(name: str | None) -> None:
    """Process-wide override (None restores env/auto resolution)."""
    global _override
    _override = None if name is None else _validate(name)


def get_backend() -> str:
    """Resolve the active backend: override > env > auto-detect."""
    if _override is not None:
        return _override
    env = os.environ.get(ENV_VAR)
    if env:
        return _validate(env)
    return PALLAS if jax.default_backend() == "tpu" else XLA


# ---------------------------------------------------------------------------
# descend / locate: multi-level fat-node descent -> leaf gather -> in-leaf
# slot (+ vhead gather).  DESIGN.md Sec 11.
# ---------------------------------------------------------------------------

@device_pass(static=("backend",))
def descend(index, queries, *, backend: str):
    """Root->leaf blocked F-way descent over ``repro.core.index``.

    Returns (bottom_node, bottom_slot, leaf_id) of the last separator
    <= q.  Trace-time dispatch: ``backend`` must be static.
    """
    if backend == XLA:
        from repro.core import index as _index

        return _index.descend(index, queries)
    from repro.kernels.uruv_search.uruv_search import index_descend

    return index_descend(
        index.node_keys, index.node_child, queries,
        interpret=(backend == PALLAS_INTERPRET),
    )


@device_pass(static=("backend",))
def locate(index, leaf_keys, leaf_vhead, queries, *, backend: str):
    """Full traversal: returns (bnode, bslot, leaf_id, slot, exists,
    vhead).  ``(bnode, bslot)`` is the bottom index entry covering the
    query (the structural delta's grouping key); ``vhead`` is -1 where
    the key is absent.  Trace-time dispatch: ``backend`` must be static.
    """
    L = leaf_keys.shape[1]
    bnode, bslot, leaf_id = descend(index, queries, backend=backend)
    rows = leaf_keys[leaf_id]                              # [P, L]
    if backend == XLA:
        slot = jnp.sum(rows < queries[:, None], axis=1).astype(jnp.int32)
        hit = jnp.take_along_axis(
            rows, jnp.minimum(slot, L - 1)[:, None], axis=1
        )[:, 0]
        exists = (slot < L) & (hit == queries)
    else:
        from repro.kernels.uruv_search.uruv_search import leaf_slots

        slot, exists = leaf_slots(rows, queries,
                                  interpret=(backend == PALLAS_INTERPRET))
    vhead = jnp.where(
        exists,
        jnp.take_along_axis(
            leaf_vhead[leaf_id], jnp.minimum(slot, L - 1)[:, None], axis=1
        )[:, 0],
        -1,
    )
    return bnode, bslot, leaf_id, slot, exists, vhead


# ---------------------------------------------------------------------------
# resolve: first version with ts <= snap (the paper's read()/vCAS path)
# ---------------------------------------------------------------------------

@device_pass(static=("max_chain", "backend"))
def resolve(vhead, snap_ts, ver_ts, ver_next, ver_value, *, max_chain: int,
            backend: str):
    """Versioned read over the chain pool; snap_ts broadcasts to vhead."""
    snap_ts = jnp.broadcast_to(jnp.asarray(snap_ts, jnp.int32), vhead.shape)
    if backend != XLA:
        from repro.kernels.versioned_read.versioned_read import versioned_read

        return versioned_read(
            vhead, snap_ts, ver_ts, ver_next, ver_value,
            max_chain=max_chain, interpret=(backend == PALLAS_INTERPRET),
        )

    def body(state):
        cur, steps = state
        ts_cur = jnp.where(cur >= 0, ver_ts[jnp.maximum(cur, 0)], 0)
        advance = (cur >= 0) & (ts_cur > snap_ts)
        nxt = jnp.where(advance, ver_next[jnp.maximum(cur, 0)], cur)
        return nxt, steps + 1

    def cond(state):
        cur, steps = state
        ts_cur = jnp.where(cur >= 0, ver_ts[jnp.maximum(cur, 0)], 0)
        return jnp.any((cur >= 0) & (ts_cur > snap_ts)) & (steps < max_chain)

    cur, _ = lax.while_loop(cond, body, (vhead, jnp.array(0, jnp.int32)))
    ok = cur >= 0
    ts_cur = jnp.where(ok, ver_ts[jnp.maximum(cur, 0)], 0)
    ok = ok & (ts_cur <= snap_ts)
    val = jnp.where(ok, ver_value[jnp.maximum(cur, 0)], NOT_FOUND)
    return jnp.where(val == TOMBSTONE, NOT_FOUND, val)


# ---------------------------------------------------------------------------
# range_scan: fused leaf-window gather + in-interval mask + versioned resolve
# (the candidate phase of store.bulk_range; paper Sec 3.4)
# ---------------------------------------------------------------------------

@device_pass(static=("max_chain", "backend"))
def range_scan(lids, pvalid, k1, k2, snap_ts, leaf_keys, leaf_vhead,
               leaf_count, ver_ts, ver_next, ver_value, *, max_chain: int,
               backend: str):
    """Candidate keys/values for Q leaf windows: (cand_keys, cand_vals) [Q, S*L].

    ``lids[q, s]`` is the s-th leaf of query q's scan window (``pvalid``
    masks non-participating slots).  Hits carry (key, value-at-snapshot);
    non-hits are (KEY_MAX, NOT_FOUND) — tombstones already dropped.
    Trace-time dispatch: call only where ``backend`` is static.
    """
    if backend != XLA:
        from repro.kernels.uruv_range.uruv_range import range_scan as _pallas_rs

        return _pallas_rs(
            lids, pvalid, k1, k2, snap_ts,
            leaf_keys, leaf_vhead, leaf_count, ver_ts, ver_next, ver_value,
            max_chain=max_chain, interpret=(backend == PALLAS_INTERPRET),
        )
    Q, S = lids.shape
    L = leaf_keys.shape[1]
    rows = leaf_keys[lids]                                 # [Q, S, L]
    vhs = leaf_vhead[lids]
    cnt = leaf_count[lids]
    slot_ok = jnp.arange(L, dtype=jnp.int32)[None, None, :] < cnt[..., None]
    cand = (
        pvalid[..., None] & slot_ok
        & (rows >= k1[:, None, None]) & (rows <= k2[:, None, None])
    )
    flat_vh = jnp.where(cand, vhs, -1).reshape(-1)
    snap = jnp.broadcast_to(snap_ts[:, None, None], cand.shape).reshape(-1)
    vals = resolve(flat_vh, snap, ver_ts, ver_next, ver_value,
                   max_chain=max_chain, backend=XLA).reshape(Q, S, L)
    hit = cand & (vals != NOT_FOUND)
    cand_keys = jnp.where(hit, rows, KEY_MAX).reshape(Q, S * L)
    cand_vals = jnp.where(hit, vals, NOT_FOUND).reshape(Q, S * L)
    return cand_keys, cand_vals
