"""Store lifecycle — proactive growth + incremental maintenance.

The paper's headline property is *proactive* structural maintenance:
splits and helping happen ahead of need, so operations never stall on a
structural wall.  The seed store had exactly such a wall — a fixed
``max_leaves`` pool behind a bump allocator, ``OFLOW_LEAVES`` when splits
exhaust it, and a stop-the-world :func:`repro.core.store.compact` as the
only reclamation.  This module removes it (DESIGN.md Sec 10):

  * :func:`grow` — device-resident pytree doubling of the leaf / version /
    tracker pools.  Pools are bucketed to powers of two (the same trick as
    ``Uruv.apply(pad_to_pow2=True)``), so a run that grows from 4K to 4M
    leaves recompiles O(log capacity) times, not once per grow.  Existing
    leaf ids, version slots and timestamps are preserved bit-exactly: the
    pools extend at the tail, nothing moves.
  * :func:`maintain` — a *bounded incremental* pass: reclaim frozen
    split-leavings and merge underfull neighbours (the paper's merge/MIN
    protocol) for at most ``budget`` leaf pairs + ``budget`` relocations
    per call.  Dead keys (head version is a tombstone at or below
    ``min_active_ts``) are physically dropped, gated by the version
    tracker — every *registered* snapshot reads byte-identical results
    before and after a pass (the same retention contract as ``compact``).
  * :class:`LifecyclePolicy` + the host triggers (:func:`lifecycle_tick`,
    :func:`relieve_pressure`) — the policy the combining layer and the
    ``repro.api`` executors wire in: auto-grow on ``OFLOW_LEAVES`` /
    ``OFLOW_VERSIONS`` instead of raising, and interleaved maintenance on
    an occupancy / frozen-fraction trigger, replacing most stop-the-world
    ``compact()`` calls.  ``CapacityError`` becomes an opt-in condition
    (``auto_grow=False``), not a steady-state failure mode.

Everything here is functional: each entry point returns a new store
pytree; prior pytrees remain valid frozen snapshots.  ``maintain`` never
touches the clock, the version pool or the tracker, and ``grow`` only
appends — so neither changes the result of any operation, and sharded
executions that interleave different lifecycle decisions stay bit-exact
with local ones.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from repro.core import index as I
from repro.core import store as S
from repro.core.ref import KEY_MAX, TOMBSTONE


# ---------------------------------------------------------------------------
# Policy
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LifecyclePolicy:
    """Host-side lifecycle policy (DESIGN.md Sec 10).

    The defaults make the store self-sizing: capacity rejections grow the
    rejected pool (power-of-two doubling) and retry, and maintenance runs
    incrementally whenever the frozen/dead fraction of the allocated pool
    crosses ``frozen_trigger``.  Set ``auto_grow=False`` to restore the
    seed behaviour (compact-then-``CapacityError``) for fixed-footprint
    deployments.
    """

    auto_grow: bool = True          # grow pools on OFLOW instead of raising
    auto_maintain: bool = True      # interleave maintain() after applies
    maintain_budget: int = 128      # leaf pairs + relocations per pass
    maintain_passes: int = 2        # max passes per interleaved trigger
    frozen_trigger: float = 0.25    # dead fraction of n_alloc that triggers
    min_dead_leaves: int = 32       # ignore dead fractions of tiny pools
    grow_occupancy: float = 0.9     # proactive: grow before the wall
    version_gc_fraction: float = 0.5  # compact() before growing versions
    pressure_passes: int = 64       # maintain burst bound under OFLOW_LEAVES


DEFAULT_POLICY = LifecyclePolicy()


# ---------------------------------------------------------------------------
# grow — device-resident pool doubling (pow2 shape bucketing)
# ---------------------------------------------------------------------------

def next_pool_size(n: int) -> int:
    """The next power-of-two bucket strictly above ``n`` (2n when n is a
    power of two) — grows are O(log capacity) distinct shapes per run."""
    return 1 << int(n).bit_length()


def _pad_dim(x: jax.Array, axis: int, size: int, fill) -> jax.Array:
    """Extend ``x`` along ``axis`` (negative: layout-agnostic, so the same
    code path serves local [ML, ...] and sharded [n_shards, ML, ...]
    stores) to ``size`` with ``fill``; existing entries are untouched."""
    old = x.shape[axis]
    if size == old:
        return x
    shape = list(x.shape)
    shape[axis % len(shape)] = size - old
    return jnp.concatenate(
        [x, jnp.full(shape, fill, x.dtype)], axis=axis % len(shape)
    )


@functools.partial(jax.jit, static_argnames=("new_ml", "new_mv", "new_mt"))
def _grow(store: S.UruvStore, *, new_ml: int, new_mv: int,
          new_mt: int) -> S.UruvStore:
    cfg = store.cfg
    new_cfg = dataclasses.replace(
        cfg, max_leaves=new_ml, max_versions=new_mv, tracker_cap=new_mt
    )
    return dataclasses.replace(
        store,
        leaf_keys=_pad_dim(store.leaf_keys, -2, new_ml, KEY_MAX),
        leaf_vhead=_pad_dim(store.leaf_vhead, -2, new_ml, -1),
        leaf_count=_pad_dim(store.leaf_count, -1, new_ml, 0),
        leaf_next=_pad_dim(store.leaf_next, -1, new_ml, -1),
        leaf_newnext=_pad_dim(store.leaf_newnext, -1, new_ml, -1),
        leaf_frozen=_pad_dim(store.leaf_frozen, -1, new_ml, False),
        leaf_ts=_pad_dim(store.leaf_ts, -1, new_ml, 0),
        index=I.grow_to(
            store.index, I.index_config(new_ml, cfg.index_fanout), new_ml,
        ),
        ver_value=_pad_dim(store.ver_value, -1, new_mv, 0),
        ver_ts=_pad_dim(store.ver_ts, -1, new_mv, 0),
        ver_next=_pad_dim(store.ver_next, -1, new_mv, -1),
        trk_ts=_pad_dim(store.trk_ts, -1, new_mt, 0),
        trk_active=_pad_dim(store.trk_active, -1, new_mt, False),
        cfg=new_cfg,
    )


def grow(store: S.UruvStore, *, leaves: bool = False, versions: bool = False,
         tracker: bool = False) -> S.UruvStore:
    """Double the selected pools on device; everything else is bit-exact.

    Capacities move to the next power-of-two bucket (``next_pool_size``),
    so repeated growth recompiles jitted consumers O(log capacity) times.
    Leaf ids, version slots, index node ids/ordinals and every timestamp
    are preserved — the pools extend at the tail (growing the leaf pool
    tail-extends every index level under the same pow2 discipline and
    stacks fresh root levels when the depth model deepens — Sec 11).
    Works on local stores and
    on stacked (sharded) stores alike: the leading device axis is left
    untouched, so every shard grows together and shard shapes stay equal
    (the sharded executor's replicated-decision requirement).
    """
    if not (leaves or versions or tracker):
        raise ValueError("grow(): select at least one pool "
                         "(leaves=, versions=, tracker=)")
    cfg = store.cfg
    return _grow(
        store,
        new_ml=next_pool_size(cfg.max_leaves) if leaves else cfg.max_leaves,
        new_mv=next_pool_size(cfg.max_versions) if versions else cfg.max_versions,
        new_mt=next_pool_size(cfg.tracker_cap) if tracker else cfg.tracker_cap,
    )


# ---------------------------------------------------------------------------
# maintain — bounded incremental reclamation + merge (paper's MIN protocol)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("budget",))
def _maintain(store: S.UruvStore, phase: jax.Array, *, budget: int):
    cfg = store.cfg
    ML, L = cfg.max_leaves, cfg.leaf_cap
    B = budget
    i32 = jnp.int32
    floor = S.min_active_ts(store)
    allpos = jnp.arange(ML, dtype=i32)

    # ---- dead-at-floor mask (tracker-gated, same retention rule as
    # compact): a slot is dead iff its head version is a tombstone at or
    # below the floor — every registered snapshot resolves it to NOT_FOUND
    # already, so dropping the key is invisible to them. -------------------
    vh = store.leaf_vhead
    vhc = jnp.maximum(vh, 0)
    occupied = jnp.arange(L, dtype=i32)[None, :] < store.leaf_count[:, None]
    dead_slot = (
        occupied & (vh >= 0)
        & (store.ver_value[vhc] == TOMBSTONE)
        & (store.ver_ts[vhc] <= floor)
    )
    live_slot = occupied & ~dead_slot
    live_cnt = jnp.sum(live_slot.astype(i32), axis=1)          # [ML]

    # ---- pair selection: adjacent leaf ordinals (p, p+1) with
    # p ≡ phase (mod 2); alternating the phase between calls covers every
    # boundary.  Eligible: the pair has purgeable dead keys, or merging
    # the live keys fits one leaf with a member under MIN (paper's merge
    # trigger).  The first `budget` eligible pairs are rewritten. --------
    NP = ML // 2
    pos = phase + 2 * jnp.arange(NP, dtype=i32)                # left ordinal
    valid = (pos + 1) < store.n_leaves
    nl1 = jnp.maximum(store.n_leaves - 1, 0)
    la = jnp.where(valid, I.leaf_at(store.index, jnp.minimum(pos, nl1)), 0)
    lb = jnp.where(
        valid, I.leaf_at(store.index, jnp.minimum(pos + 1, nl1)), 0)
    live_a, live_b = live_cnt[la], live_cnt[lb]
    # merge when a member is under the paper's MIN, or when the pair is
    # jointly at most half-full (the merged leaf then needs >= L/2 fresh
    # inserts before it can split again — no split/merge thrash).  The
    # right member's separator must be deletable from its bottom index
    # node (slot >= 1: entry keys are subtree lower bounds — Sec 11);
    # skipped pairs become eligible again after a reindex repack.
    mergeable = valid & (live_a + live_b <= L) & (
        (live_a < cfg.min_fill) | (live_b < cfg.min_fill)
        | (live_a + live_b <= L // 2)
    ) & I.merge_deletable(store.index, jnp.minimum(pos + 1, nl1))
    has_dead = valid & (
        (live_a < store.leaf_count[la]) | (live_b < store.leaf_count[lb])
    )
    eligible = mergeable | has_dead
    rank = jnp.cumsum(eligible.astype(i32)) - 1
    sel = jnp.where(eligible & (rank < B), rank, B)            # scatter idx
    pair_pos = jnp.full((B,), ML, i32).at[sel].set(pos, mode="drop")
    pair_a = jnp.full((B,), 0, i32).at[sel].set(la, mode="drop")
    pair_b = jnp.full((B,), 0, i32).at[sel].set(lb, mode="drop")
    pair_merge = jnp.zeros((B,), bool).at[sel].set(mergeable, mode="drop")
    pair_real = pair_pos < ML

    # ---- rewrite the selected pairs: purge dead keys; merge when the
    # union fits (right leaf cleared + marked frozen = retired garbage) --
    keys_a = jnp.where(live_slot[pair_a], store.leaf_keys[pair_a], KEY_MAX)
    vh_a = jnp.where(live_slot[pair_a], store.leaf_vhead[pair_a], -1)
    keys_b = jnp.where(live_slot[pair_b], store.leaf_keys[pair_b], KEY_MAX)
    vh_b = jnp.where(live_slot[pair_b], store.leaf_vhead[pair_b], -1)
    mk, mv_ = lax.sort(
        (jnp.concatenate([keys_a, keys_b], axis=1),
         jnp.concatenate([vh_a, vh_b], axis=1)),
        dimension=1, num_keys=1,
    )                                                          # [B, 2L]
    pk_a, pv_a = lax.sort((keys_a, vh_a), dimension=1, num_keys=1)
    pk_b, pv_b = lax.sort((keys_b, vh_b), dimension=1, num_keys=1)
    la_live, lb_live = live_cnt[pair_a], live_cnt[pair_b]
    merge = pair_real & pair_merge
    out_a_keys = jnp.where(merge[:, None], mk[:, :L], pk_a)
    out_a_vh = jnp.where(merge[:, None], mv_[:, :L], pv_a)
    out_a_cnt = jnp.where(merge, la_live + lb_live, la_live)
    out_b_keys = jnp.where(merge[:, None], KEY_MAX, pk_b)
    out_b_vh = jnp.where(merge[:, None], -1, pv_b)
    out_b_cnt = jnp.where(merge, 0, lb_live)

    wa = jnp.where(pair_real, pair_a, ML)
    wb = jnp.where(pair_real, pair_b, ML)
    leaf_keys = store.leaf_keys.at[wa].set(out_a_keys, mode="drop")
    leaf_vhead = store.leaf_vhead.at[wa].set(out_a_vh, mode="drop")
    leaf_count = store.leaf_count.at[wa].set(out_a_cnt, mode="drop")
    leaf_keys = leaf_keys.at[wb].set(out_b_keys, mode="drop")
    leaf_vhead = leaf_vhead.at[wb].set(out_b_vh, mode="drop")
    leaf_count = leaf_count.at[wb].set(out_b_cnt, mode="drop")
    leaf_frozen = store.leaf_frozen.at[
        jnp.where(merge, pair_b, ML)
    ].set(True, mode="drop")
    n_merged = jnp.sum(merge.astype(i32))

    # ---- index delta: delete the right members' separators (bounded —
    # O(budget · F); replaces the old O(ML) directory compaction).  The
    # left member keeps its separator (all right keys exceed it), so the
    # separator order stays strict and ordinal 0 stays KEY_MIN. ----------
    index1 = I.apply_merge_delta(
        store.index, jnp.minimum(pair_pos + 1, ML - 1), pair_b, merge)
    n_leaves1 = store.n_leaves - n_merged
    # chain splice: the left member inherits the merged-away successor
    leaf_next = store.leaf_next.at[
        jnp.where(merge, pair_a, ML)
    ].set(store.leaf_next[pair_b], mode="drop")

    # ---- bounded relocation: move up to `budget` of the highest live
    # leaves into the lowest dead slots, then release the all-dead tail
    # of the bump allocator.  Dead slots that stay below the new n_alloc
    # remain frozen garbage for a later pass — the work per call is
    # bounded, the reclamation is incremental.  The reverse map makes the
    # index fixup O(budget) (the old path remapped the whole directory).
    ref = index1.leaf_ent >= 0              # referenced by the index
    alloc = allpos < store.n_alloc
    dead = alloc & ~ref
    drank = jnp.cumsum(dead.astype(i32)) - 1
    dst = jnp.full((B,), ML, i32).at[
        jnp.where(dead & (drank < B), drank, B)
    ].set(allpos, mode="drop")
    rrank = jnp.cumsum(ref[::-1].astype(i32))[::-1] - 1        # from the top
    src = jnp.full((B,), -1, i32).at[
        jnp.where(ref & (rrank < B), rrank, B)
    ].set(allpos, mode="drop")
    do = (dst < ML) & (src >= 0) & (src > dst)
    srcc = jnp.where(do, src, 0)
    dstc = jnp.where(do, dst, ML)
    leaf_keys = leaf_keys.at[dstc].set(leaf_keys[srcc], mode="drop")
    leaf_vhead = leaf_vhead.at[dstc].set(leaf_vhead[srcc], mode="drop")
    leaf_count = leaf_count.at[dstc].set(leaf_count[srcc], mode="drop")
    leaf_ts = store.leaf_ts.at[dstc].set(store.leaf_ts[srcc], mode="drop")
    leaf_frozen = leaf_frozen.at[dstc].set(False, mode="drop")
    leaf_newnext = store.leaf_newnext.at[dstc].set(-1, mode="drop")

    # chain fixups for the moved leaves (bounded scatters): the copied
    # next pointer and the predecessor's link follow the relocation map
    remap = allpos.at[jnp.where(do, src, ML)].set(
        jnp.where(do, dst, 0), mode="drop")
    nxt_src = leaf_next[srcc]
    leaf_next = leaf_next.at[dstc].set(
        jnp.where(nxt_src >= 0, remap[jnp.maximum(nxt_src, 0)], -1),
        mode="drop")
    Fi = cfg.index_fanout
    ent = index1.leaf_ent[srcc]
    ordv = I.leaf_ordinal(index1, jnp.maximum(ent, 0) // Fi,
                          jnp.maximum(ent, 0) % Fi)
    has_pred = do & (ordv > 0)
    pred = I.leaf_at(index1, jnp.maximum(ordv - 1, 0))
    leaf_next = leaf_next.at[
        jnp.where(has_pred, remap[jnp.maximum(pred, 0)], ML)
    ].set(jnp.where(do, dst, -1), mode="drop")

    # index entry retarget (reverse-map lookup; O(budget))
    index2 = I.retarget_leaves(index1, src, dst, do)
    ref2 = index2.leaf_ent >= 0
    n_alloc = jnp.maximum(jnp.max(jnp.where(ref2, allpos + 1, 0)), 1) \
        .astype(i32)

    # freed tail: scrub so the bump allocator can hand the slots out again
    freed = alloc & (allpos >= n_alloc)
    leaf_keys = jnp.where(freed[:, None], KEY_MAX, leaf_keys)
    leaf_vhead = jnp.where(freed[:, None], -1, leaf_vhead)
    leaf_count = jnp.where(freed, 0, leaf_count)
    leaf_frozen = jnp.where(freed, False, leaf_frozen)
    leaf_newnext = jnp.where(freed, -1, leaf_newnext)
    leaf_ts = jnp.where(freed, 0, leaf_ts)
    leaf_next = jnp.where(freed, -1, leaf_next)

    reclaimed = store.n_alloc - n_alloc
    new = dataclasses.replace(
        store,
        leaf_keys=leaf_keys,
        leaf_vhead=leaf_vhead,
        leaf_count=leaf_count,
        leaf_next=leaf_next,
        leaf_newnext=leaf_newnext,
        leaf_frozen=leaf_frozen,
        leaf_ts=leaf_ts,
        n_alloc=n_alloc,
        index=index2,
        n_leaves=n_leaves1,
    )
    return new, reclaimed, n_merged


def maintain(
    store: S.UruvStore, budget: int = 128, *, phase: int = 0,
) -> Tuple[S.UruvStore, int, int]:
    """ONE bounded incremental maintenance pass (device-resident).

    Rewrites at most ``budget`` adjacent leaf pairs — purging keys whose
    head version is a tombstone at or below ``min_active_ts`` (the version
    tracker gate) and merging neighbours whose live keys fit one leaf with
    a member under the paper's MIN — then relocates at most ``budget``
    live leaves downward to release the dead tail of the leaf bump
    allocator (frozen split-leavings and merged-away leaves).

    Returns ``(store, leaves_reclaimed, pairs_merged)``.  Never touches
    the clock, the version pool, or the tracker: every operation result —
    including reads at any *registered* snapshot — is byte-identical
    before and after the pass.  Alternate ``phase`` (0/1) between calls so
    both pair parities of the directory are covered.  A stacked (sharded)
    store dispatches through ``jax.vmap`` — every shard maintains in the
    same call, so shard shapes stay equal (the replicated-decision rule).
    """
    ph = jnp.asarray(phase % 2, jnp.int32)
    if np.asarray(store.ts).ndim:          # stacked (sharded) store
        fn = jax.vmap(functools.partial(_maintain, budget=budget),
                      in_axes=(0, None))
        new, reclaimed, merged = fn(store, ph)
    else:
        new, reclaimed, merged = _maintain(store, ph, budget=budget)
    return new, int(np.asarray(reclaimed).sum()), int(np.asarray(merged).sum())


# ---------------------------------------------------------------------------
# Per-pool dirty watermarks (delta checkpoints, DESIGN.md Sec 14)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PoolWatermarks:
    """Host snapshot of the pool allocators at one clock value.

    The delta-checkpoint writer (``repro.checkpoint.manager``) compares
    two of these to decide what can be skipped without reading it:
    ``grow`` only tail-extends (pow2 buckets, prefixes bit-exact) and the
    version pool is a bump allocator that structural passes never rewrite
    except :func:`repro.core.store.compact` — so between saves with no
    compaction, version slots below the older ``n_vers`` are immutable
    and the delta is exactly the tail slice.  ``compactions`` is the
    caller-supplied pass counter (``Uruv.stats``) that invalidates that
    reasoning when it moves.
    """

    ts: int
    n_alloc: int
    n_vers: int
    n_leaves: int
    max_leaves: int
    max_versions: int
    tracker_cap: int
    compactions: int = 0


def pool_watermarks(store: S.UruvStore, *,
                    compactions: int = 0) -> PoolWatermarks:
    """Read the allocator watermarks (one host transfer; sharded stores
    report per-shard maxima — the tail fast path below then disables
    itself, see :func:`version_tail_start`)."""
    ts, n_alloc, n_vers, n_leaves = jax.device_get(
        (store.ts, store.n_alloc, store.n_vers, store.n_leaves))
    return PoolWatermarks(
        ts=int(np.asarray(ts).max()),
        n_alloc=int(np.asarray(n_alloc).max()),
        n_vers=int(np.asarray(n_vers).max()),
        n_leaves=int(np.asarray(n_leaves).max()),
        max_leaves=int(store.cfg.max_leaves),
        max_versions=int(store.cfg.max_versions),
        tracker_cap=int(store.cfg.tracker_cap),
        compactions=compactions,
    )


def version_tail_start(before: PoolWatermarks, store: S.UruvStore, *,
                       compactions: int = 0) -> Optional[int]:
    """The append-only fast path for delta checkpoints: the first version
    slot that may differ from the state ``before`` describes, or ``None``
    when tail stability cannot be guaranteed (a compaction ran, the pool
    is stacked/sharded, or the allocator moved backwards) and the writer
    must fall back to a full row diff."""
    if compactions != before.compactions:
        return None
    if np.asarray(store.ts).ndim:          # stacked: per-shard allocators
        return None
    n_vers = int(np.asarray(store.n_vers))
    if n_vers < before.n_vers:
        return None
    return before.n_vers


# ---------------------------------------------------------------------------
# Host-side occupancy accounting + triggers
# ---------------------------------------------------------------------------

def leaf_accounting(store: S.UruvStore) -> Dict[str, int]:
    """Bump-allocator accounting (host-side; sharded stores sum shards).

    Invariant (tested): every allocated slot is either live (referenced by
    the directory, not frozen) or dead (frozen — a retired split-leaving
    or merged-away leaf awaiting reclamation):
    ``n_alloc == live + dead`` and ``dead == frozen_allocated``.
    """
    n_alloc = int(np.asarray(store.n_alloc).sum())
    live = int(np.asarray(store.n_leaves).sum())
    frozen = np.asarray(store.leaf_frozen)
    alloc_mask = (
        np.arange(frozen.shape[-1])[None, :]
        < np.asarray(store.n_alloc).reshape(-1, 1)
    )
    dead = int((frozen.reshape(alloc_mask.shape) & alloc_mask).sum())
    return {
        "n_alloc": n_alloc,
        "live": live,
        "dead": dead,
        "capacity": int(store.cfg.max_leaves)
        * (np.asarray(store.ts).size),
    }


def live_key_count(store: S.UruvStore) -> int:
    """Total keys held by index-referenced leaves (host-side; frozen
    leavings keep stale counts and are excluded).  Tombstoned keys count
    until maintenance purges them — this is a pool-occupancy figure, not
    a liveness oracle."""
    lc = np.asarray(store.leaf_count)
    ref = np.asarray(store.index.leaf_ent) >= 0   # same shape, incl. stacked
    return int(lc[ref].sum())


def dead_fraction(store: S.UruvStore) -> float:
    """Dead (unreferenced-but-allocated) fraction of the leaf pool."""
    n_alloc = int(np.asarray(store.n_alloc).sum())
    live = int(np.asarray(store.n_leaves).sum())
    return (n_alloc - live) / max(n_alloc, 1)


def run_maintenance(
    store: S.UruvStore, policy: LifecyclePolicy, *,
    stats: Optional[Dict[str, int]] = None, max_passes: Optional[int] = None,
    maintain_fn=None,
) -> S.UruvStore:
    """Bounded burst of maintain passes with alternating phase.

    Stops after ``max_passes`` (default ``policy.maintain_passes``), when a
    pass reclaims and merges nothing, or when the dead fraction falls
    under half the trigger.  ``maintain_fn(store, budget, phase)``
    overrides the local pass (the sharded executor supplies its vmapped
    one); the burst/trigger/accounting loop is shared either way.
    """
    if maintain_fn is None:
        def maintain_fn(st, budget, phase):
            return maintain(st, budget, phase=phase)
    passes = max_passes if max_passes is not None else policy.maintain_passes
    for p in range(passes):
        store, reclaimed, merged = maintain_fn(
            store, policy.maintain_budget, p % 2
        )
        if stats is not None:
            stats["maintain_passes"] = stats.get("maintain_passes", 0) + 1
            stats["leaves_reclaimed"] = (
                stats.get("leaves_reclaimed", 0) + reclaimed
            )
        if reclaimed == 0 and merged == 0:
            break
        if dead_fraction(store) < policy.frozen_trigger / 2:
            break
    return store


def lifecycle_tick(
    store: S.UruvStore, policy: LifecyclePolicy, *,
    stats: Optional[Dict[str, int]] = None, grow_fn=None, maintain_fn=None,
) -> S.UruvStore:
    """The post-apply interleave BOTH executors share: a bounded maintain
    burst on the frozen-fraction trigger FIRST (reclaiming frozen leaves
    is cheaper than a permanent doubling and often drops occupancy back
    under the growth trigger), then proactive growth re-checked on the
    maintained store.  One ``device_get`` serves both triggers — the
    apply path already synced on ``ok``, so the tick adds at most one
    extra blocking transfer.  ``grow_fn(store)`` / ``maintain_fn(store,
    budget, phase)`` let a topology wrap its own passes (the sharded
    executor reshards after each) without duplicating the trigger logic.
    """
    if not (policy.auto_grow or policy.auto_maintain):
        return store
    n_alloc_raw, n_leaves_raw = jax.device_get(
        (store.n_alloc, store.n_leaves))
    n_alloc = int(np.asarray(n_alloc_raw).sum())
    dead = n_alloc - int(np.asarray(n_leaves_raw).sum())
    if (policy.auto_maintain and dead >= policy.min_dead_leaves
            and dead / max(n_alloc, 1) >= policy.frozen_trigger):
        store = run_maintenance(store, policy, stats=stats,
                                maintain_fn=maintain_fn)
        n_alloc_raw = jax.device_get(store.n_alloc)
    if (policy.auto_grow
            and int(np.asarray(n_alloc_raw).max())
            > policy.grow_occupancy * store.cfg.max_leaves):
        if grow_fn is None:
            if stats is not None:
                stats["grows"] = stats.get("grows", 0) + 1
            store = grow(store, leaves=True)
        else:
            store = grow_fn(store)
    return store


def relieve_pressure(
    store: S.UruvStore, reason: int, width: int, policy: LifecyclePolicy, *,
    stats: Optional[Dict[str, int]] = None,
) -> S.UruvStore:
    """One pressure-relief step for a capacity-rejected batch (host policy).

    ``OFLOW_LEAVES``: when the dead fraction is above the trigger, burst
    ``maintain`` (reclaiming frozen garbage is cheaper than growing);
    otherwise — or if the burst freed nothing — double the leaf pool.
    ``OFLOW_VERSIONS``: ``compact()`` first when the pool is mostly-full
    garbage candidate (the tracker-gated GC), then double the version pool
    until the batch provably fits.  ``OFLOW_INDEX``: the fat-node pools
    are fragmented (or the root overflowed) — :func:`S.reindex` repacks
    them at pack_fill, which always frees enough slots for the retry.
    The caller retries the device pass after each step; every step
    strictly increases free capacity, so the retry loop converges.
    """
    if reason & S.OFLOW_INDEX:
        if stats is not None:
            stats["reindexes"] = stats.get("reindexes", 0) + 1
        store = S.reindex(store)
    if reason & S.OFLOW_LEAVES:
        before = int(np.asarray(store.n_alloc).sum())
        if dead_fraction(store) >= policy.frozen_trigger:
            store = run_maintenance(
                store, policy, stats=stats,
                max_passes=policy.pressure_passes,
            )
        if int(np.asarray(store.n_alloc).sum()) >= before:
            store = grow(store, leaves=True)
            if stats is not None:
                stats["grows"] = stats.get("grows", 0) + 1
    if reason & S.OFLOW_VERSIONS:
        cfg = store.cfg
        n_vers = int(np.asarray(store.n_vers).max())
        # compact() can reclaim at most sum(n_vers) - live_keys versions
        # (every key that survives retains >= 1): pure-ingest pools with
        # no version history have nothing to give back — skip the
        # stop-the-world pass and grow directly.
        reclaimable_bound = (
            int(np.asarray(store.n_vers).sum()) - live_key_count(store)
        )
        if (reclaimable_bound >= width
                and n_vers >= policy.version_gc_fraction * cfg.max_versions):
            if stats is not None:
                stats["compactions"] = stats.get("compactions", 0) + 1
            if np.asarray(store.ts).ndim:          # stacked (sharded) store
                store, _ = jax.vmap(S.compact)(store)
            else:
                store, _ = S.compact(store)
            n_vers = int(np.asarray(store.n_vers).max())
        while n_vers + width > store.cfg.max_versions:
            store = grow(store, versions=True)
            if stats is not None:
                stats["grows"] = stats.get("grows", 0) + 1
    return store
