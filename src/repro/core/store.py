"""UruvStore — the paper's B+-tree + MVCC key-value store, TPU-native.

Structure (DESIGN.md Sec 2):

  * Leaf pool   — SoA "fat leaf" arrays ``leaf_keys[ML, L]`` (sorted rows,
    ``KEY_MAX`` padded) + ``leaf_vhead[ML, L]`` (version-chain heads).  Leaves
    are chained (``leaf_next``) exactly like the paper's linked leaf level and
    carry a creation timestamp ``leaf_ts`` and ``newnext``/``frozen`` fields
    mirroring the paper's split protocol.
  * Index       — the internal fat-node index (``repro.core.index``): a
    multi-level tree of F-wide nodes over the leaf separators, kept balanced
    by *proactive, local* split/merge exactly as the paper prescribes.
    Structural batches emit a bounded separator delta (one insert per leaf
    split, one delete per leaf merge) applied level-by-level bottom-up;
    restructuring propagates only on node overflow — O(touched·F·depth)
    work per batch, never an O(ML) rebuild (DESIGN.md Sec 11).
  * Version pool — SoA ``Vnode``s: ``ver_value/ver_ts/ver_next`` with a bump
    allocator.  DELETE writes a TOMBSTONE version (paper Sec 3.2); physical
    reclamation is incremental in steady state (``repro.core.lifecycle.
    maintain`` purges dead keys and reclaims retired leaves) with
    :func:`compact` as the rare stop-the-world version-pool GC — both
    gated by the version tracker (paper Appendix E).  Pools are not a
    wall: ``lifecycle.grow`` doubles them device-resident on pressure
    (DESIGN.md Sec 10).
  * Version tracker — ring of (snapshot ts, active) entries; ``min_active_ts``
    gates GC.

Wait-freedom (paper Sec 4, adapted): a batch *is* the announce array.  Every
op in the batch completes in one deterministic data-parallel pass
(O(L + log n + sort(P)) depth).  Conflicting ops on one key are ordered by
announce rank (timestamp = base_ts + announce index), which is precisely the
linearization the helping protocol of Kogan-Petrank produces.  If a batch
over-concentrates new keys on one leaf (more than L new keys into a single
leaf) the pass aborts atomically with ``ok=False`` and the combining layer
(``repro.core.batch``) falls back to the *slow path*: smaller rounds that are
guaranteed to make progress — the fast-path/slow-path structure of the paper.

Everything is fixed-shape, jit-compatible, and functional: each update
returns a new store pytree.  The old pytree remains a valid frozen snapshot
(the paper's freeze-and-copy for free).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.analysis.marks import device_pass
from repro.core import backend as _B
from repro.core import index as _I
from repro.core.ref import (
    KEY_MAX, NOT_FOUND, TOMBSTONE, OP_DELETE, OP_INSERT, OP_NOP, OP_SEARCH,
)

KEY_MIN = _I.KEY_MIN  # index sentinel for the left-most separator

# Overflow flag bits (store.oflow)
OFLOW_VERSIONS = 1
OFLOW_LEAVES = 2
OFLOW_TRACKER = 4
OFLOW_LEAFBATCH = 8   # > L new keys routed to a single leaf (slow-path signal)
OFLOW_INDEX = 16      # index node pool / root overflow -> lifecycle reindex


@dataclasses.dataclass(frozen=True)
class UruvConfig:
    """Static capacities (compile-time constants)."""

    leaf_cap: int = 32          # L — max keys per leaf (paper's MAX)
    max_leaves: int = 4096      # ML — leaf pool size
    max_versions: int = 1 << 16  # MV — version pool size
    tracker_cap: int = 128      # MT — version-tracker ring size
    max_chain: int = 64         # bound on version-chain walks / GC retention
    index_fanout: int = 16      # F — entries per internal fat node (Sec 11)

    @property
    def min_fill(self) -> int:  # paper's MIN
        return self.leaf_cap // 4

    @property
    def pack_fill(self) -> int:  # occupancy target after compact()
        return max(1, (3 * self.leaf_cap) // 4)

    def index_config(self) -> "_I.IndexConfig":
        """Static index geometry derived from (max_leaves, index_fanout):
        depth = levels to cover ML separators at >= F/2 node fill, caps
        pow2-bucketed per level (DESIGN.md Sec 11)."""
        return _I.index_config(self.max_leaves, self.index_fanout)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class UruvStore:
    # --- leaf pool ---
    leaf_keys: jax.Array    # int32 [ML, L], sorted rows, KEY_MAX padded
    leaf_vhead: jax.Array   # int32 [ML, L], -1 where empty
    leaf_count: jax.Array   # int32 [ML]
    leaf_next: jax.Array    # int32 [ML], -1 = end (paper: next)
    leaf_newnext: jax.Array  # int32 [ML], -1 = unset (paper: newNext)
    leaf_frozen: jax.Array  # bool  [ML] (paper: frozen)
    leaf_ts: jax.Array      # int32 [ML] creation timestamp (paper: ts)
    n_alloc: jax.Array      # int32 [] bump allocator over the leaf pool
    # --- internal index (multi-level fat nodes; repro.core.index) ---
    index: _I.UruvIndex
    n_leaves: jax.Array     # int32 [] live leaves (== live separators)
    # --- version pool ---
    ver_value: jax.Array    # int32 [MV]
    ver_ts: jax.Array       # int32 [MV]
    ver_next: jax.Array     # int32 [MV], -1 = end
    n_vers: jax.Array       # int32 []
    # --- clock + tracker ---
    ts: jax.Array           # int32 [] global timestamp (paper's FAA counter)
    trk_ts: jax.Array       # int32 [MT]
    trk_active: jax.Array   # bool  [MT]
    trk_cursor: jax.Array   # int32 [] ring cursor
    # --- status ---
    oflow: jax.Array        # int32 [] bitmask of OFLOW_*
    cfg: UruvConfig = dataclasses.field(metadata=dict(static=True))


def create(cfg: UruvConfig = UruvConfig()) -> UruvStore:
    ML, L, MV, MT = cfg.max_leaves, cfg.leaf_cap, cfg.max_versions, cfg.tracker_cap
    i32 = jnp.int32
    store = UruvStore(
        leaf_keys=jnp.full((ML, L), KEY_MAX, i32),
        leaf_vhead=jnp.full((ML, L), -1, i32),
        leaf_count=jnp.zeros((ML,), i32),
        leaf_next=jnp.full((ML,), -1, i32),
        leaf_newnext=jnp.full((ML,), -1, i32),
        leaf_frozen=jnp.zeros((ML,), bool),
        leaf_ts=jnp.zeros((ML,), i32),
        n_alloc=jnp.array(1, i32),              # leaf 0 is the initial empty leaf
        index=_I.build(
            cfg.index_config(), ML,
            jnp.full((ML,), KEY_MAX, i32).at[0].set(KEY_MIN),
            jnp.full((ML,), -1, i32).at[0].set(0),
            jnp.array(1, i32),
        ),
        n_leaves=jnp.array(1, i32),
        ver_value=jnp.zeros((MV,), i32),
        ver_ts=jnp.zeros((MV,), i32),
        ver_next=jnp.full((MV,), -1, i32),
        n_vers=jnp.array(0, i32),
        ts=jnp.array(0, i32),
        trk_ts=jnp.zeros((MT,), i32),
        trk_active=jnp.zeros((MT,), bool),
        trk_cursor=jnp.array(0, i32),
        oflow=jnp.array(0, i32),
        cfg=cfg,
    )
    return store


# ---------------------------------------------------------------------------
# Locate: multi-level fat-node descent + in-leaf position (the traversal of
# Fig. 1).  Dispatched through repro.core.backend: the Pallas kernels
# (repro.kernels.uruv_search / versioned_read) and the XLA oracle share one
# contract; ``backend`` must be static at every call site.
# ---------------------------------------------------------------------------

@device_pass(static=("backend",))
def _locate(store: UruvStore, keys: jax.Array, backend: str = _B.XLA):
    """Vectorized root->leaf traversal.

    Returns (bnode, bslot, leaf_id, slot, exists, vhead) per query key;
    (bnode, bslot) is the bottom index entry covering the key — the
    structural phase's grouping handle (DESIGN.md Sec 11).
    """
    return _B.locate(
        store.index, store.leaf_keys, store.leaf_vhead,
        keys, backend=backend,
    )


@device_pass(static=("backend",))
def _resolve(
    store: UruvStore, vhead: jax.Array, snap_ts: jax.Array,
    backend: str = _B.XLA,
) -> jax.Array:
    """Versioned read: first version with ts <= snap (paper's read()/vCAS path).

    Bounded chain walk (cfg.max_chain); the Pallas kernel
    repro.kernels.versioned_read mirrors this contract.
    """
    return _B.resolve(
        vhead, snap_ts, store.ver_ts, store.ver_next, store.ver_value,
        max_chain=store.cfg.max_chain, backend=backend,
    )


# ---------------------------------------------------------------------------
# SEARCH (batched)
# ---------------------------------------------------------------------------

@device_pass(static=("backend",))
@functools.partial(jax.jit, static_argnames=("backend",))
def _bulk_lookup(store, keys, snap_ts, *, backend):
    snap_ts = jnp.broadcast_to(jnp.asarray(snap_ts, jnp.int32), keys.shape)
    _, _, _, _, exists, vhead = _locate(store, keys, backend)
    vals = _resolve(store, jnp.where(exists, vhead, -1), snap_ts, backend)
    return jnp.where(keys >= KEY_MAX, NOT_FOUND, vals)


def bulk_lookup(
    store: UruvStore, keys: jax.Array, snap_ts: jax.Array,
    *, backend: str | None = None,
) -> jax.Array:
    """Batched SEARCH at per-op snapshot timestamps.

    ``snap_ts`` may be scalar or [P].  Padded (KEY_MAX) keys return NOT_FOUND.
    Read-only: does not advance the clock (the combining layer assigns op
    timestamps; see repro.core.batch).  Thin wrapper over the shared
    locate/resolve primitives of :func:`bulk_apply` (DESIGN.md Sec 3).
    """
    return _bulk_lookup(store, keys, snap_ts,
                        backend=backend or _B.get_backend())


# ---------------------------------------------------------------------------
# bulk_apply — ONE device pass over a mixed announce array (the tentpole of
# DESIGN.md Sec 3).  SEARCH / INSERT / DELETE / NOP complete together: op i
# runs at timestamp op_ts[i] (default base_ts + i), updates append versions
# stamped with their op timestamp, and searches resolve at their own
# per-op snapshot — the batch analogue of the paper's single announce-array
# scan (Kogan-Petrank helping).
# ---------------------------------------------------------------------------

@device_pass(static=("backend", "light_path"))
def _bulk_apply_impl(store, op_codes, keys, values, base_ts, op_ts, next_ts,
                     backend, light_path=True):
    cfg = store.cfg
    P = keys.shape[0]
    L, ML, MV = cfg.leaf_cap, cfg.max_leaves, cfg.max_versions
    i32 = jnp.int32
    if base_ts is None:
        base_ts = store.ts
    base_ts = jnp.asarray(base_ts, i32)
    if op_ts is None:
        op_ts = base_ts + jnp.arange(P, dtype=i32)
    op_ts = jnp.asarray(op_ts, i32)
    if next_ts is None:
        next_ts = base_ts + P
    next_ts = jnp.asarray(next_ts, i32)
    announce = jnp.arange(P, dtype=i32)

    is_upd = (op_codes == OP_INSERT) | (op_codes == OP_DELETE)
    is_search = op_codes == OP_SEARCH
    valid_key = keys < KEY_MAX
    adt_keys = jnp.where((is_upd | is_search) & valid_key, keys, KEY_MAX)
    upd_vals = jnp.where(op_codes == OP_DELETE, TOMBSTONE, values).astype(i32)

    # ---- sort by (key, announce idx): groups duplicates, keeps LP order ----
    # Searches ride in the SAME sort as updates: the whole batch shares one
    # directory descent + leaf gather, and each search reads its in-batch
    # predecessor directly (no post-apply second locate) — the fused pass.
    skeys, sidx, svals, scodes = lax.sort(
        (adt_keys, announce, upd_vals, op_codes), num_keys=2
    )
    svalid = skeys < KEY_MAX
    upd_s = svalid & ((scodes == OP_INSERT) | (scodes == OP_DELETE))
    search_s = svalid & (scodes == OP_SEARCH)
    sop_ts = op_ts[sidx]       # per-op timestamps (announce-order monotone)
    first_occ = jnp.concatenate([jnp.ones((1,), bool), skeys[1:] != skeys[:-1]])
    first_occ &= svalid

    # ---- locate all ops: ONE descent for updates and searches -------------
    bnode, bslot, leaf_id, slot, exists, old_vhead = _locate(
        store, skeys, backend)
    exists &= svalid
    F_I = cfg.index_fanout
    ENT_PAD = cfg.index_config().caps[0] * F_I     # grouping sentinel
    ent = bnode * F_I + bslot                      # bottom index entry id

    # ---- version slots: bump-allocate one per update op -------------------
    vofs = jnp.cumsum(upd_s.astype(i32)) - 1
    vslot = jnp.where(upd_s, store.n_vers + vofs, MV)         # MV == dropped
    nval = jnp.sum(upd_s.astype(i32))

    # in-batch predecessor: the latest *update* before op i in its key group
    # (searches interleave freely).  pred[i] = sorted position of that
    # update, or -1 when op i only sees the pre-batch chain.
    pos_arr = jnp.arange(P, dtype=i32)
    seg_start = _cummax(jnp.where(first_occ, pos_arr, -1))
    upd_pos = jnp.where(upd_s, pos_arr, -1)
    m_incl = _cummax(upd_pos)
    m_excl = jnp.concatenate([jnp.full((1,), -1, i32), m_incl[:-1]])
    pred = jnp.where(m_excl >= seg_start, m_excl, -1)

    # chain: first update of a group links to the old vhead, later ones to
    # their in-batch predecessor's version slot
    vnext = jnp.where(pred >= 0, vslot[jnp.maximum(pred, 0)], old_vhead)
    vts = sop_ts

    # per-op predecessor value (sequential semantics inside the batch):
    # updates report it as their previous value; searches short-circuit to
    # it when it exists (its timestamp is < theirs by op_ts monotonicity)
    pred_val = _tomb(svals[jnp.maximum(pred, 0)])
    head_val = jnp.where(exists, _latest_value(store, old_vhead), NOT_FOUND)
    prev_vals_sorted = jnp.where(
        upd_s, jnp.where(pred >= 0, pred_val, head_val), NOT_FOUND
    )

    # searches with no in-batch predecessor resolve on the PRE-batch chain
    # at their own snapshot (versions this batch writes all carry ts >=
    # base_ts > any pre-batch version's, so old-store resolution is exact)
    rhead = jnp.where(search_s & (pred < 0) & exists, old_vhead, -1)
    resolved = lax.cond(
        jnp.any(rhead >= 0),
        lambda: _resolve(store, rhead, sop_ts, backend),
        lambda: jnp.full((P,), NOT_FOUND, i32),
    )
    search_vals_sorted = jnp.where(
        search_s,
        jnp.where(pred >= 0, pred_val, resolved),
        NOT_FOUND,
    )

    # per-group new vhead = version slot of the group's LAST update (stored
    # at the group's first position, where the structural phase reads it)
    last_upd_of_seg = jnp.full((P,), -1, i32).at[
        jnp.where(svalid, seg_start, P - 1)
    ].max(upd_pos)
    group_vhead = jnp.where(
        last_upd_of_seg >= 0, vslot[jnp.maximum(last_upd_of_seg, 0)], -1
    )
    # per-op view: position of the last update in MY group
    lus = last_upd_of_seg[jnp.maximum(seg_start, 0)]

    # ---- new-key groups (structural inserts) -------------------------------
    # a group is a structural insert iff its key is absent AND it contains
    # at least one update (search-only groups on missing keys insert nothing)
    is_new = first_occ & (~exists) & (last_upd_of_seg >= 0)
    n_new = jnp.sum(is_new.astype(i32))
    # compact new entries to the front, preserving key order
    order = jnp.argsort(jnp.where(is_new, 0, 1).astype(i32), stable=True)
    ckeys = skeys[order]
    cvhead = group_vhead[order]
    cent = jnp.where(is_new[order], ent[order], ENT_PAD)      # pad sentinel
    cleaf = leaf_id[order]
    crank = jnp.arange(P, dtype=i32)
    cval = crank < n_new

    boundary = cval & jnp.concatenate(
        [jnp.ones((1,), bool), cent[1:] != cent[:-1]]
    )
    gid = jnp.cumsum(boundary.astype(i32)) - 1                # group index t
    gstart = _cummax(jnp.where(boundary, crank, -1))
    goffset = crank - gstart                                   # index within group
    n_groups = jnp.sum(boundary.astype(i32))

    # per-group metadata (padded to P groups)
    gent = jnp.full((P,), ENT_PAD, i32).at[
        jnp.where(boundary, gid, P - 1)
    ].min(jnp.where(boundary, cent, ENT_PAD))                  # index entry id
    gcount = jnp.zeros((P,), i32).at[
        jnp.where(cval, gid, P - 1)
    ].add(jnp.where(cval, 1, 0))
    g_is_real = jnp.arange(P) < n_groups
    gleafs = jnp.full((P,), ML, i32).at[
        jnp.where(boundary, gid, P - 1)
    ].min(jnp.where(boundary, cleaf, ML))
    gleaf = jnp.where(g_is_real, jnp.minimum(gleafs, ML - 1), 0)
    gold_count = jnp.where(g_is_real, store.leaf_count[gleaf], 0)
    # pre-batch leaf ordinal of each group (leaf_next adjacency below)
    gord = _I.leaf_ordinal(
        store.index,
        jnp.where(g_is_real, gent // F_I, 0),
        jnp.where(g_is_real, gent % F_I, 0),
    )

    # slow-path signal: more than L new keys for one leaf
    leaf_batch_ovf = jnp.any(gcount > L)
    n_splits = jnp.sum((g_is_real & (gold_count + gcount > L)).astype(i32))

    pre_overflow = (
        jnp.where(store.n_vers + nval > MV, OFLOW_VERSIONS, 0)
        | jnp.where(store.n_alloc + 2 * n_splits > ML, OFLOW_LEAVES, 0)
        | jnp.where(store.n_leaves + n_splits > ML, OFLOW_LEAVES, 0)
        | jnp.where(leaf_batch_ovf, OFLOW_LEAFBATCH, 0)
    ).astype(i32)

    # ---- existing-key vhead updates (group's last update only) ----
    upd = upd_s & exists & (pos_arr == lus)
    u_leaf = jnp.where(upd, leaf_id, ML)
    leaf_vhead0 = store.leaf_vhead.at[u_leaf, slot].set(vslot, mode="drop")

    def _apply_structural(leaf_vhead):
        # ---- structural phase: merge new keys into touched leaves ----
        # workspace [P groups, 2L]
        wk_keys = jnp.full((P, 2 * L), KEY_MAX, i32)
        wk_vh = jnp.full((P, 2 * L), -1, i32)
        wk_keys = wk_keys.at[:, :L].set(
            jnp.where(g_is_real[:, None], store.leaf_keys[gleaf], KEY_MAX)
        )
        wk_vh = wk_vh.at[:, :L].set(
            jnp.where(g_is_real[:, None], leaf_vhead[gleaf], -1)
        )
        # scatter new (key, vhead) pairs at L + offset within their group row
        row = jnp.where(cval, gid, P - 1)
        col = jnp.where(cval, L + jnp.minimum(goffset, L - 1), 2 * L)
        wk_keys = wk_keys.at[row, col].set(
            jnp.where(cval, ckeys, KEY_MAX), mode="drop"
        )
        wk_vh = wk_vh.at[row, col].set(jnp.where(cval, cvhead, -1), mode="drop")
        wk_keys, wk_vh = lax.sort((wk_keys, wk_vh), dimension=1, num_keys=1)

        merged = gold_count + gcount                          # [P]
        split = g_is_real & (merged > L)
        lc = jnp.where(split, (merged + 1) // 2, merged)

        # allocate new leaves for splits: (left, right) per split, in order
        sofs = jnp.cumsum(split.astype(i32)) - 1
        left_id = jnp.where(split, store.n_alloc + 2 * sofs, ML)
        right_id = jnp.where(split, left_id + 1, ML)
        n_alloc = store.n_alloc + 2 * n_splits

        colidx = jnp.arange(2 * L, dtype=i32)[None, :]
        # in-place rewrite (no split): write merged row back to gleaf
        ip = g_is_real & (~split)
        ip_leaf = jnp.where(ip, gleaf, ML)
        leaf_keys = store.leaf_keys.at[ip_leaf, :].set(wk_keys[:, :L], mode="drop")
        leaf_vhead = leaf_vhead.at[ip_leaf, :].set(wk_vh[:, :L], mode="drop")
        leaf_count = store.leaf_count.at[ip_leaf].set(merged, mode="drop")

        # split: left half -> left_id, right half -> right_id
        lmask = colidx < lc[:, None]
        lk = jnp.where(lmask, wk_keys, KEY_MAX)[:, :L]
        lv = jnp.where(lmask, wk_vh, -1)[:, :L]
        shift = jnp.minimum(colidx + lc[:, None], 2 * L - 1)
        rk_full = jnp.take_along_axis(wk_keys, shift, axis=1)
        rv_full = jnp.take_along_axis(wk_vh, shift, axis=1)
        rmask = colidx < (merged - lc)[:, None]
        rk = jnp.where(rmask, rk_full, KEY_MAX)[:, :L]
        rv = jnp.where(rmask, rv_full, -1)[:, :L]

        leaf_keys = leaf_keys.at[left_id, :].set(lk, mode="drop")
        leaf_vhead = leaf_vhead.at[left_id, :].set(lv, mode="drop")
        leaf_count = leaf_count.at[left_id].set(lc, mode="drop")
        leaf_keys = leaf_keys.at[right_id, :].set(rk, mode="drop")
        leaf_vhead = leaf_vhead.at[right_id, :].set(rv, mode="drop")
        leaf_count = leaf_count.at[right_id].set(merged - lc, mode="drop")

        leaf_ts = store.leaf_ts.at[left_id].set(base_ts, mode="drop")
        leaf_ts = leaf_ts.at[right_id].set(base_ts, mode="drop")
        # paper's split protocol bookkeeping: old leaf frozen, newNext -> left
        old_split_leaf = jnp.where(split, gleaf, ML)
        leaf_frozen = store.leaf_frozen.at[old_split_leaf].set(True, mode="drop")
        leaf_newnext = store.leaf_newnext.at[old_split_leaf].set(
            left_id, mode="drop"
        )

        # ---- leaf_next delta (bounded; replaces the old chain rebuild):
        # left half takes the old leaf's chain position, right half links
        # to the old successor — unless the successor leaf split too, in
        # which case it links to THAT split's left half.  In-place merges
        # keep their leaf id, so their links are already exact. ----------
        old_nexts = store.leaf_next[gleaf]                    # pre-batch chain
        adj = jnp.concatenate(
            [(gord[1:] == gord[:-1] + 1), jnp.zeros((1,), bool)])
        nxt_split_adj = adj & jnp.concatenate(
            [split[1:], jnp.zeros((1,), bool)])
        nxt_left = jnp.concatenate([left_id[1:], jnp.full((1,), ML, i32)])
        prev_split_adj = jnp.concatenate(
            [jnp.zeros((1,), bool), split[:-1]]) & jnp.concatenate(
            [jnp.zeros((1,), bool), adj[:-1]])
        leaf_next = store.leaf_next.at[
            jnp.where(split, left_id, ML)
        ].set(jnp.where(split, right_id, -1), mode="drop")
        rnext = jnp.where(nxt_split_adj, nxt_left, old_nexts)
        leaf_next = leaf_next.at[
            jnp.where(split, right_id, ML)
        ].set(jnp.where(split, rnext, -1), mode="drop")
        pred_leaf = _I.leaf_at(store.index, jnp.maximum(gord - 1, 0))
        w_pred = jnp.where(split & (gord > 0) & ~prev_split_adj,
                           pred_leaf, ML)
        leaf_next = leaf_next.at[w_pred].set(
            jnp.where(split, left_id, -1), mode="drop")

        # ---- index delta: ONE separator insert per split, applied
        # level-by-level bottom-up; node splits propagate only on
        # overflow (the paper's proactive balancing — DESIGN.md Sec 11).
        # Untouched separators keep their (lower-bound) keys. -----------
        e1_key = jnp.take_along_axis(
            wk_keys, jnp.minimum(lc, 2 * L - 1)[:, None], axis=1
        )[:, 0]
        new_index, idx_oflow = _I.apply_split_delta(
            store.index, split, wk_keys[:, 0], gleaf, left_id, right_id,
            e1_key,
        )
        new_n_leaves = store.n_leaves + n_splits

        return (leaf_keys, leaf_vhead, leaf_count, leaf_next, leaf_newnext,
                leaf_frozen, leaf_ts, n_alloc, new_index, new_n_leaves,
                idx_oflow)

    def _skip_structural(leaf_vhead):
        return (store.leaf_keys, leaf_vhead, store.leaf_count,
                store.leaf_next, store.leaf_newnext, store.leaf_frozen,
                store.leaf_ts, store.n_alloc, store.index, store.n_leaves,
                jnp.zeros((), bool))

    # Structural work (workspace merge-sort, splits, index delta) is only
    # needed when the batch introduces new keys; version-only batches (the
    # common read/overwrite-heavy case) skip it entirely.  light_path=False
    # reproduces the pre-bulk_apply behaviour (unconditional structural
    # pass) — the benchmark baseline.  The phase runs speculatively (the
    # index delta's own overflow check feeds the atomic reject below).
    run_struct = (pre_overflow == 0) & (
        (n_new > 0) if light_path else jnp.ones((), bool))
    (s_leaf_keys, s_leaf_vhead, s_leaf_count, s_leaf_next, s_leaf_newnext,
     s_leaf_frozen, s_leaf_ts, s_n_alloc, s_index, s_n_leaves,
     idx_oflow) = lax.cond(
        run_struct, _apply_structural, _skip_structural, leaf_vhead0)

    overflow = pre_overflow | jnp.where(idx_oflow, OFLOW_INDEX, 0).astype(i32)
    ok = overflow == 0

    def apply(store: UruvStore) -> UruvStore:
        # ---- version pool writes ----
        ver_value = store.ver_value.at[vslot].set(svals, mode="drop")
        ver_ts = store.ver_ts.at[vslot].set(vts, mode="drop")
        ver_next = store.ver_next.at[vslot].set(vnext, mode="drop")
        n_vers = store.n_vers + nval

        return dataclasses.replace(
            store,
            leaf_keys=s_leaf_keys,
            leaf_vhead=s_leaf_vhead,
            leaf_count=s_leaf_count,
            leaf_next=s_leaf_next,
            leaf_newnext=s_leaf_newnext,
            leaf_frozen=s_leaf_frozen,
            leaf_ts=s_leaf_ts,
            n_alloc=s_n_alloc,
            index=s_index,
            n_leaves=s_n_leaves,
            ver_value=ver_value,
            ver_ts=ver_ts,
            ver_next=ver_next,
            n_vers=n_vers,
            ts=next_ts,
            oflow=store.oflow,
        )

    def reject(store: UruvStore) -> UruvStore:
        return dataclasses.replace(store, oflow=store.oflow | overflow)

    new_store = lax.cond(ok, apply, reject, store)

    # un-sort per-op results back to announce order (search results were
    # resolved in-sort: predecessor value or pre-batch chain — the batch is
    # its own per-op-snapshot answer, no second locate needed)
    res_sorted = jnp.where(search_s, search_vals_sorted, prev_vals_sorted)
    results = jnp.zeros((P,), i32).at[sidx].set(res_sorted)
    results = jnp.where(ok, results, NOT_FOUND)
    return new_store, results, ok


@device_pass(static=("backend", "light_path"))
@functools.partial(jax.jit, static_argnames=("backend", "light_path"))
def _bulk_apply(store, op_codes, keys, values, base_ts, op_ts, next_ts, *,
                backend, light_path=True):
    return _bulk_apply_impl(store, op_codes, keys, values, base_ts, op_ts,
                            next_ts, backend, light_path)


# Store-donating twin of `_bulk_apply` for the pipelined serving front end
# (repro.api.Uruv.apply_nowait / serve.coalescer, DESIGN.md Sec 12): the
# pools double-buffer in place instead of allocating a fresh copy per pass.
# Only the store is donated — every pool aliases a same-shape output, so
# the donation is always usable; the small announce arrays are not (they
# alias nothing and would just warn).  Donating the store is only safe for
# an exclusive owner: rejection (`ok=False`) passes the pools through
# untouched, so the pre-pass state remains recoverable from the RETURNED
# store, but any OTHER live reference to the donated buffers (a
# `from_store` donor, a held `db.store`) is invalidated.
@device_pass(static=("backend", "light_path"))
@functools.partial(jax.jit, static_argnames=("backend", "light_path"),
                   donate_argnums=(0,))
def _bulk_apply_dstore(store, op_codes, keys, values, base_ts, op_ts, next_ts,
                       *, backend, light_path=True):
    return _bulk_apply_impl(store, op_codes, keys, values, base_ts, op_ts,
                            next_ts, backend, light_path)


def bulk_apply(
    store: UruvStore,
    op_codes: jax.Array,
    keys: jax.Array,
    values: jax.Array,
    base_ts=None,
    *,
    op_ts=None,
    next_ts=None,
    backend: str | None = None,
    light_path: bool = True,
    donate_store: bool = False,
) -> Tuple[UruvStore, jax.Array, jax.Array]:
    """Apply a mixed announce array in ONE jitted device pass.

    ``op_codes[i]`` in {OP_SEARCH, OP_INSERT, OP_DELETE, OP_NOP}.  Op i runs
    at timestamp ``op_ts[i]`` (default ``base_ts + i``; ``base_ts`` defaults
    to ``store.ts``) and the clock advances to ``next_ts`` (default
    ``base_ts + P``).  Results are in announce order: INSERT/DELETE return
    the previous value, SEARCH the value at its per-op snapshot, NOP/padded
    (KEY_MAX) keys NOT_FOUND.

    ``op_ts`` must be strictly increasing in announce order (the default and
    the sharded router both satisfy this); it exists so a shard can apply a
    routed *subset* of a global announce array while preserving the global
    announce-order linearization (DESIGN.md Sec 3).

    ``ok=False`` means the batch was rejected atomically (capacity overflow
    or > L new keys for one leaf) and must be retried via the slow path
    (``repro.core.batch`` halves it, preserving per-op timestamps).

    Searches and updates share ONE directory descent (the sort carries op
    codes); a search reads its in-batch predecessor's value directly —
    exact regardless of how many same-key updates precede it — and only
    falls back to the bounded (``cfg.max_chain``) pre-batch chain walk when
    its key was not updated earlier in the batch.

    Recognized codes are SEARCH/INSERT/DELETE/NOP only: OP_RANGE must flow
    through ``repro.core.batch.apply_batch`` (which segments the announce
    array and answers range ops via :func:`bulk_range`); an unrecognized
    code here degrades to NOP.

    ``donate_store`` donates the store pools into the pass (the serving
    pipeline's in-place double buffer) — see the donation-safety note on
    ``_bulk_apply_dstore`` above.
    """
    fn = _bulk_apply_dstore if donate_store else _bulk_apply
    return fn(
        store,
        jnp.asarray(op_codes, jnp.int32),
        jnp.asarray(keys, jnp.int32),
        jnp.asarray(values, jnp.int32),
        base_ts, op_ts, next_ts,
        backend=backend or _B.get_backend(),
        light_path=light_path,
    )


def derive_update_codes(keys: jax.Array, values: jax.Array) -> jax.Array:
    """Op codes for the legacy (keys, values) update encoding:
    KEY_MAX key -> NOP, TOMBSTONE value -> DELETE, otherwise INSERT."""
    return jnp.where(
        keys >= KEY_MAX, OP_NOP,
        jnp.where(values == TOMBSTONE, OP_DELETE, OP_INSERT),
    ).astype(jnp.int32)


def bulk_update(
    store: UruvStore, keys: jax.Array, values: jax.Array,
    *, op_ts=None, next_ts=None, backend: str | None = None,
    light_path: bool = True,
) -> Tuple[UruvStore, jax.Array, jax.Array]:
    """DEPRECATED — use ``repro.api.Uruv.apply(OpBatch.updates(keys, values))``
    (or :func:`bulk_apply` for the raw single-pass primitive).

    Legacy INSERT/DELETE encoding (DELETE == value TOMBSTONE, KEY_MAX keys
    are no-ops); delegates to :func:`bulk_apply` with derived op codes, the
    same pass the ``repro.api`` client issues, so results are bit-exact
    with the client path.  Returns (new_store, prev_values[P], ok);
    ``ok=False`` means the batch was rejected atomically and must be
    retried via the slow path.
    """
    import warnings

    warnings.warn(
        "repro.core.store.bulk_update is deprecated; use "
        "repro.api.Uruv.apply(OpBatch.updates(keys, values))",
        DeprecationWarning, stacklevel=2,
    )
    keys = jnp.asarray(keys, jnp.int32)
    values = jnp.asarray(values, jnp.int32)
    return bulk_apply(
        store, derive_update_codes(keys, values), keys, values,
        op_ts=op_ts, next_ts=next_ts, backend=backend, light_path=light_path,
    )


def _latest_value(store: UruvStore, vhead: jax.Array) -> jax.Array:
    ok = vhead >= 0
    val = jnp.where(ok, store.ver_value[jnp.maximum(vhead, 0)], NOT_FOUND)
    return _tomb(val)


def _tomb(val: jax.Array) -> jax.Array:
    return jnp.where(val == TOMBSTONE, NOT_FOUND, val)


def _cummax(x: jax.Array) -> jax.Array:
    return lax.associative_scan(jnp.maximum, x)


# ---------------------------------------------------------------------------
# RANGEQUERY
# ---------------------------------------------------------------------------

@device_pass(static=("max_scan_leaves", "max_results", "backend"))
@functools.partial(
    jax.jit, static_argnames=("max_scan_leaves", "max_results", "backend")
)
def _range_query(
    store: UruvStore,
    k1: jax.Array,
    k2: jax.Array,
    snap_ts: jax.Array,
    *,
    max_scan_leaves: int,
    max_results: int,
    backend: str,
):
    cfg = store.cfg
    L, ML = cfg.leaf_cap, cfg.max_leaves
    i32 = jnp.int32
    k1 = jnp.asarray(k1, i32)
    k2 = jnp.asarray(k2, i32)
    snap_ts = jnp.asarray(snap_ts, i32)

    bn1, bs1, _ = _I.descend(store.index, k1[None])
    lo = _I.leaf_ordinal(store.index, bn1, bs1)[0]
    ppos = lo + jnp.arange(max_scan_leaves, dtype=i32)
    pvalid = ppos < store.n_leaves
    ppos_c = jnp.minimum(ppos, jnp.maximum(store.n_leaves - 1, 0))
    # a leaf participates if its separator <= k2 (first leaf always does)
    sep = jnp.where(pvalid, _I.sep_at(store.index, ppos_c), KEY_MAX)
    pvalid &= (sep <= k2) | (ppos == lo)
    lids = jnp.where(pvalid, _I.leaf_at(store.index, ppos_c), 0)

    keys = store.leaf_keys[lids]                             # [S, L]
    vheads = store.leaf_vhead[lids]
    counts = store.leaf_count[lids]
    slot_ok = jnp.arange(L, dtype=i32)[None, :] < counts[:, None]
    kmask = pvalid[:, None] & slot_ok & (keys >= k1) & (keys <= k2)

    flat_vh = jnp.where(kmask, vheads, -1).reshape(-1)
    flat_keys = jnp.where(kmask, keys, KEY_MAX).reshape(-1)
    vals = _resolve(store, flat_vh, snap_ts, backend)
    hit = (flat_keys < KEY_MAX) & (vals != NOT_FOUND)

    # compact hits to the front (sorted by key), take max_results
    sort_k = jnp.where(hit, flat_keys, KEY_MAX)
    sk, sv = lax.sort((sort_k, vals), num_keys=1)
    count = jnp.minimum(jnp.sum(hit.astype(i32)), max_results)
    out_keys = sk[:max_results]
    out_vals = jnp.where(out_keys < KEY_MAX, sv[:max_results], NOT_FOUND)
    out_keys = jnp.where(out_keys < KEY_MAX, out_keys, KEY_MAX)

    # truncated if the scan window closed before covering k2
    last_pos = lo + max_scan_leaves
    more_leaves = (last_pos < store.n_leaves) & (
        _I.sep_at(store.index,
                  jnp.minimum(last_pos, jnp.maximum(store.n_leaves - 1, 0)))
        <= k2
    )
    truncated = more_leaves | (jnp.sum(hit.astype(i32)) > max_results)
    return out_keys, out_vals, count, truncated


def range_query(
    store: UruvStore,
    k1: jax.Array,
    k2: jax.Array,
    snap_ts: jax.Array,
    *,
    max_scan_leaves: int = 64,
    max_results: int = 1024,
    backend: str | None = None,
):
    """Snapshot range scan (paper Sec 3.4 / Fig. 11).

    Walks the chained leaf level from the first leaf that may contain k1,
    resolving each key's version at ``snap_ts`` and dropping tombstones.
    Returns (keys[max_results], values[max_results], count, truncated).
    ``truncated`` means the scan window (max_scan_leaves) ended before k2 —
    the host continues with k1' = last returned key + 1 (pagination), so the
    overall scan is still wait-free: each call is one bounded pass.
    """
    return _range_query(
        store, k1, k2, snap_ts,
        max_scan_leaves=max_scan_leaves, max_results=max_results,
        backend=backend or _B.get_backend(),
    )


# ---------------------------------------------------------------------------
# bulk_range — ONE device pass over a whole announce array of range queries
# (the range-search analogue of bulk_apply; DESIGN.md Sec 8).  All Q
# intervals share one index descent (two batched multi-level rank passes
# give every query its exact leaf window [lo, hi)); the windows are flattened
# into ONE pooled (query, leaf) worklist so narrow queries donate unscanned
# budget to wide ones, and the leaf gather + version resolve over the
# worklist is fused in repro.kernels.uruv_range.
# ---------------------------------------------------------------------------

@device_pass(static=("max_results", "scan_leaves", "max_rounds", "backend"))
@functools.partial(
    jax.jit,
    static_argnames=("max_results", "scan_leaves", "max_rounds", "backend"),
)
def _bulk_range(store, k1, k2, snap_ts, *, max_results, scan_leaves,
                max_rounds, backend):
    cfg = store.cfg
    L, ML = cfg.leaf_cap, cfg.max_leaves
    i32 = jnp.int32
    Q = k1.shape[0]
    R = max_results
    T = Q * scan_leaves * max_rounds      # pooled leaf budget for this pass

    # ---- shared index descent: rank k1 AND k2 for every query ------------
    # ONE batched multi-level descent over both endpoint arrays (the
    # kernel's blocked F-way descent under pallas*), then the ordinal
    # spine converts bottom entries to global leaf ordinals.
    bn, bs, _ = _B.descend(
        store.index, jnp.concatenate([k1, k2]), backend=backend)
    ords = _I.leaf_ordinal(store.index, bn, bs)
    lo = ords[:Q]                                  # last separator <= k1
    hi = ords[Q:] + 1                              # first ordinal past k2
    hi = jnp.minimum(jnp.maximum(hi, lo + 1), store.n_leaves)
    # leaves needed: lo is always scanned for a real interval; inverted
    # intervals (k1 > k2) get a zero-width window so they are complete
    # empty results even when the pooled budget runs dry (never truncated)
    n_win = jnp.where(k1 > k2, 0, jnp.maximum(hi - lo, 1))

    # ---- flat worklist: task t -> (query qid[t], leaf position ppos[t]) ---
    offs = jnp.cumsum(n_win) - n_win      # exclusive prefix over windows
    total = offs[Q - 1] + n_win[Q - 1]
    t = jnp.arange(T, dtype=i32)
    qid = jnp.clip(_I.rank(offs, t, side="right") - 1, 0, Q - 1)
    tvalid = t < total
    ppos = lo[qid] + (t - offs[qid])
    tvalid &= ppos < store.n_leaves
    lids = jnp.where(
        tvalid,
        _I.leaf_at(store.index,
                   jnp.minimum(ppos, jnp.maximum(store.n_leaves - 1, 0))),
        0,
    )

    # ---- fused gather + in-interval mask + versioned resolve (kernel) -----
    cand_keys, cand_vals = _B.range_scan(
        lids[:, None], tvalid[:, None], k1[qid], k2[qid], snap_ts[qid],
        store.leaf_keys, store.leaf_vhead, store.leaf_count,
        store.ver_ts, store.ver_next, store.ver_value,
        max_chain=cfg.max_chain, backend=backend,
    )                                     # [T, L]

    # ---- per-query compaction WITHOUT sorting: the worklist is laid out
    # per query in leaf order and every leaf row is key-sorted, so the
    # flat candidate stream is already (query, key)-ordered.  A running
    # hit count + binary search recovers each query's r-th hit by gather
    # (a full lax.sort here costs more than the rest of the pass). --------
    hit = cand_keys.reshape(-1) < KEY_MAX
    N = T * L
    csum = jnp.cumsum(hit.astype(i32))                    # inclusive [N]
    n_hits_total = csum[N - 1]
    flat_start = jnp.minimum(offs, T) * L                 # query q's slice of
    flat_end = jnp.minimum(offs + n_win, T) * L           # the scanned stream
    hits_before = jnp.where(
        flat_start > 0, csum[jnp.maximum(flat_start - 1, 0)], 0
    )
    n_hit = csum[jnp.maximum(flat_end - 1, 0)] - hits_before
    n_hit = jnp.where(flat_end > flat_start, n_hit, 0)
    count = jnp.minimum(n_hit, R)
    g = hits_before[:, None] + jnp.arange(R, dtype=i32)[None, :]
    in_seg = jnp.arange(R, dtype=i32)[None, :] < count[:, None]
    idx = _I.rank(csum, jnp.minimum(g + 1, n_hits_total), side="left")
    idxc = jnp.minimum(idx, N - 1)
    out_keys = jnp.where(in_seg, cand_keys.reshape(-1)[idxc], KEY_MAX)
    out_vals = jnp.where(in_seg, cand_vals.reshape(-1)[idxc], NOT_FOUND)

    # ---- truncation + resume (pagination contract) ------------------------
    scanned = jnp.clip(T - offs, 0, n_win)   # leaves this pass covered
    covered = scanned == n_win
    overflow = n_hit > R
    truncated = overflow | (~covered)
    # resume point for truncated queries:
    #   * result-block overflow -> last kept key + 1 (re-scan dropped keys)
    #   * budget exhausted      -> separator of the first unscanned leaf
    #     (every scanned key is < that separator: nothing skipped or
    #     duplicated); 0 leaves scanned resumes at k1 unchanged — the pooled
    #     worklist always finishes earlier queries first, so every pass
    #     makes progress.
    last_key = jnp.take_along_axis(
        out_keys, jnp.maximum(count - 1, 0)[:, None], axis=1
    )[:, 0]
    unscanned_sep = jnp.where(
        scanned > 0,
        _I.sep_at(store.index,
                  jnp.minimum(lo + scanned,
                              jnp.maximum(store.n_leaves - 1, 0))),
        k1,
    )
    resume_k1 = jnp.where(
        overflow, last_key + 1, jnp.where(~covered, unscanned_sep, k2)
    )
    return out_keys, out_vals, count, truncated, resume_k1


def bulk_range(
    store: UruvStore,
    k1: jax.Array,
    k2: jax.Array,
    snap_ts: jax.Array,
    *,
    max_results: int = 1024,
    scan_leaves: int = 16,
    max_rounds: int = 8,
    backend: str | None = None,
):
    """Batched snapshot range scan: Q intervals in ONE jitted device pass.

    ``k1[i], k2[i]`` bound query i (inclusive; ``k1 > k2`` yields an empty
    result) and ``snap_ts`` (scalar or [Q]) is each query's snapshot — the
    RANGEQUERY LP of paper Sec 3.4, resolved per key by the fused
    ``uruv_range`` kernel.  Returns
    ``(keys[Q, max_results], values[Q, max_results], count[Q],
    truncated[Q], resume_k1[Q])`` with rows key-sorted and KEY_MAX /
    NOT_FOUND padded.

    Pagination happens IN-PASS: the pass carries a pooled leaf budget of
    ``Q * scan_leaves * max_rounds`` tasks (one bounded data-parallel
    step — the wait-free bound), distributed by NEED: each query's exact
    window [lo, hi) comes from the shared descent and the windows are
    flattened into one worklist, so a point query costs one leaf and the
    budget it didn't use covers wide scans instead of being burned on
    fixed per-query windows.  ``truncated[i]`` means query i's interval
    was not fully covered — the result block overflowed ``max_results`` or
    the pooled budget ran out before its window — and ``resume_k1[i]`` is
    the exact key to resume from (``repro.core.batch.bulk_range_all``
    host-paginates only the still-truncated queries).

    Read-only: does not advance the clock or touch the tracker (callers
    register snapshots via :func:`snapshot` / :func:`release`).
    """
    k1 = jnp.asarray(k1, jnp.int32)
    snap_ts = jnp.broadcast_to(jnp.asarray(snap_ts, jnp.int32), k1.shape)
    return _bulk_range(
        store, k1, jnp.asarray(k2, jnp.int32), snap_ts,
        max_results=max_results, scan_leaves=scan_leaves,
        max_rounds=max_rounds, backend=backend or _B.get_backend(),
    )


# ---------------------------------------------------------------------------
# Snapshots + version tracker (paper Appendix E)
# ---------------------------------------------------------------------------

@device_pass
@jax.jit
def snapshot(store: UruvStore) -> Tuple[UruvStore, jax.Array]:
    """RANGEQUERY LP: read the clock, register in the tracker ring.

    Registers in a FREE slot whenever one exists (long-held registrations
    are never evicted by churning short-lived ones — the incremental
    maintenance of ``repro.core.lifecycle`` relies on ``min_active_ts``
    honouring every live registration); only a genuinely full ring evicts
    the cursor slot and flags ``OFLOW_TRACKER`` (under the default
    lifecycle policy the executor grows the ring before that happens).
    """
    snap = store.ts
    free = ~store.trk_active
    lost = ~jnp.any(free)         # ring truly full: evict + flag
    cur = jnp.where(
        lost, store.trk_cursor % store.cfg.tracker_cap,
        jnp.argmax(free).astype(jnp.int32),
    )
    trk_ts = store.trk_ts.at[cur].set(snap)
    trk_active = store.trk_active.at[cur].set(True)
    new = dataclasses.replace(
        store,
        ts=store.ts + 1,
        trk_ts=trk_ts,
        trk_active=trk_active,
        trk_cursor=store.trk_cursor + 1,
        oflow=store.oflow | jnp.where(lost, OFLOW_TRACKER, 0).astype(jnp.int32),
    )
    return new, snap


@device_pass
@jax.jit
def release(store: UruvStore, snap_ts: jax.Array) -> UruvStore:
    match = store.trk_active & (store.trk_ts == snap_ts)
    # release one matching entry (the oldest)
    idx = jnp.argmax(match)
    any_match = jnp.any(match)
    trk_active = store.trk_active.at[jnp.where(any_match, idx, store.cfg.tracker_cap)].set(
        False, mode="drop"
    )
    return dataclasses.replace(store, trk_active=trk_active)


@device_pass
@jax.jit
def min_active_ts(store: UruvStore) -> jax.Array:
    return jnp.min(jnp.where(store.trk_active, store.trk_ts, store.ts))


# ---------------------------------------------------------------------------
# COMPACT — physical reclamation + proactive merge/repack (paper Appendix E:
# "Every time we merge or split, we physically remove deleted keys ...").
# In the bulk-synchronous design this is a global repack: drop versions no
# active snapshot can read, drop dead keys, rebuild perfectly packed leaves.
# ---------------------------------------------------------------------------

@device_pass
@jax.jit
def compact(store: UruvStore) -> Tuple[UruvStore, jax.Array]:
    """Rebuild the store, reclaiming versions below min_active_ts.

    Per key we retain: every version with ts > floor, plus the single
    resolved version at the floor — bounded to cfg.max_chain retained
    versions (documented retention bound; DESIGN.md Sec 2).
    Returns (new_store, n_live_keys).
    """
    cfg = store.cfg
    L, ML, MV, D = cfg.leaf_cap, cfg.max_leaves, cfg.max_versions, cfg.max_chain
    i32 = jnp.int32
    floor = min_active_ts(store)

    # gather all live keys in index order -> flat [ML*L]
    allp = jnp.arange(ML, dtype=i32)
    order_leaf = jnp.where(
        allp < store.n_leaves,
        _I.leaf_at(store.index,
                   jnp.minimum(allp, jnp.maximum(store.n_leaves - 1, 0))),
        0,
    )
    live_rows = jnp.arange(ML) < store.n_leaves
    keys = jnp.where(live_rows[:, None], store.leaf_keys[order_leaf], KEY_MAX)
    vhs = jnp.where(live_rows[:, None], store.leaf_vhead[order_leaf], -1)
    slot_ok = jnp.arange(L)[None, :] < store.leaf_count[order_leaf][:, None]
    keys = jnp.where(slot_ok, keys, KEY_MAX).reshape(-1)
    vhs = jnp.where(slot_ok.reshape(-1), vhs.reshape(-1), -1)
    N = keys.shape[0]

    # walk each chain up to depth D, collecting retained versions.
    def step(carry, _):
        cur, kept, reached_floor = carry
        ok = cur >= 0
        ts_c = jnp.where(ok, store.ver_ts[jnp.maximum(cur, 0)], 0)
        keep_this = ok & (~reached_floor)
        at_or_below = ok & (ts_c <= floor)
        out = (jnp.where(keep_this, cur, -1), keep_this)
        reached_floor = reached_floor | at_or_below
        nxt = jnp.where(ok, store.ver_next[jnp.maximum(cur, 0)], -1)
        return (nxt, kept + keep_this.astype(i32), reached_floor), out

    init = (vhs, jnp.zeros((N,), i32), jnp.zeros((N,), bool))
    (_, kept_n, _), (kept_idx, kept_mask) = lax.scan(
        step, init, None, length=D
    )
    kept_idx = kept_idx.T          # [N, D], newest-first
    kept_mask = kept_mask.T

    # live key = resolved *latest* value is not a tombstone OR it has history
    # a snapshot >= floor can still read. We keep any key whose retained chain
    # is non-empty and not (single tombstone at/below floor).
    head_val = jnp.where(vhs >= 0, store.ver_value[jnp.maximum(vhs, 0)], NOT_FOUND)
    only_old_tomb = (
        (kept_n == 1)
        & (head_val == TOMBSTONE)
        & (jnp.where(vhs >= 0, store.ver_ts[jnp.maximum(vhs, 0)], 0) <= floor)
    )
    live = (keys < KEY_MAX) & (kept_n > 0) & (~only_old_tomb)

    # compact live keys to front (they are already key-sorted in dir order)
    corder = jnp.argsort(jnp.where(live, 0, 1).astype(i32), stable=True)
    ckeys = jnp.where(live[corder], keys[corder], KEY_MAX)
    ckept_idx = kept_idx[corder]
    ckept_mask = kept_mask[corder]
    n_live = jnp.sum(live.astype(i32))

    # rebuild the version pool: new slot per retained version
    flat_keep = ckept_mask.reshape(-1)
    new_slot_flat = jnp.cumsum(flat_keep.astype(i32)) - 1
    new_slot = jnp.where(ckept_mask, new_slot_flat.reshape(ckept_mask.shape), -1)
    n_new_vers = jnp.sum(flat_keep.astype(i32))
    src = jnp.maximum(ckept_idx, 0).reshape(-1)
    dst = jnp.where(flat_keep, new_slot_flat, MV)
    ver_value = jnp.zeros((MV,), i32).at[dst].set(store.ver_value[src], mode="drop")
    ver_ts = jnp.zeros((MV,), i32).at[dst].set(store.ver_ts[src], mode="drop")
    # chain: version j links to version j+1 of the same key (newest-first)
    nxt_in_key = jnp.concatenate(
        [new_slot[:, 1:], jnp.full((N, 1), -1, i32)], axis=1
    ).reshape(-1)
    ver_next = jnp.full((MV,), -1, i32).at[dst].set(
        jnp.where(nxt_in_key >= 0, nxt_in_key, -1), mode="drop"
    )
    new_vhead = new_slot[:, 0]

    # rebuild packed leaves at pack_fill occupancy
    F = cfg.pack_fill
    n_new_leaves = jnp.maximum((n_live + F - 1) // F, 1)
    kidx = jnp.arange(N, dtype=i32)
    dleaf = kidx // F
    dslot = kidx % F
    kvalid = kidx < n_live
    leaf_keys = jnp.full((ML, L), KEY_MAX, i32).at[
        jnp.where(kvalid, dleaf, ML), dslot
    ].set(ckeys, mode="drop")
    leaf_vhead = jnp.full((ML, L), -1, i32).at[
        jnp.where(kvalid, dleaf, ML), dslot
    ].set(new_vhead, mode="drop")
    lrange = jnp.arange(ML, dtype=i32)
    leaf_count = jnp.clip(n_live - lrange * F, 0, F).astype(i32)
    leaf_count = jnp.where(lrange < n_new_leaves, leaf_count, 0)
    leaf_next = jnp.where(
        lrange + 1 < n_new_leaves, lrange + 1, -1
    ).astype(i32)
    # rebuild the index from scratch — compact is the stop-the-world path,
    # so a fresh packed build (pack_fill node occupancy) is the right
    # trade; cumulative index counters survive the rebuild
    sep_keys = jnp.where(
        lrange < n_new_leaves,
        leaf_keys[jnp.minimum(lrange, ML - 1), 0],
        KEY_MAX,
    ).astype(i32)
    sep_keys = sep_keys.at[0].set(KEY_MIN)
    sep_leaf = jnp.where(lrange < n_new_leaves, lrange, -1).astype(i32)
    new_index = dataclasses.replace(
        _I.build(cfg.index_config(), ML, sep_keys, sep_leaf,
                 n_new_leaves.astype(i32)),
        stat_delta_passes=store.index.stat_delta_passes,
        stat_propagations=store.index.stat_propagations,
    )

    new = dataclasses.replace(
        store,
        leaf_keys=leaf_keys,
        leaf_vhead=leaf_vhead,
        leaf_count=leaf_count,
        leaf_next=leaf_next,
        leaf_newnext=jnp.full((ML,), -1, i32),
        leaf_frozen=jnp.zeros((ML,), bool),
        leaf_ts=jnp.full((ML,), store.ts, i32),
        n_alloc=n_new_leaves.astype(i32),
        index=new_index,
        n_leaves=n_new_leaves.astype(i32),
        ver_value=ver_value,
        ver_ts=ver_ts,
        ver_next=ver_next,
        n_vers=n_new_vers,
        oflow=jnp.array(0, jnp.int32),
    )
    return new, n_live


# ---------------------------------------------------------------------------
# Index maintenance hooks (host-callable; see repro.core.lifecycle)
# ---------------------------------------------------------------------------

def reindex(store: UruvStore) -> UruvStore:
    """Stop-the-world index repack (pack_fill occupancy) — the recovery
    path for ``OFLOW_INDEX`` (node-pool fragmentation after heavy
    delete/merge churn).  Leaves, versions, clock and tracker are
    untouched: every operation result is byte-identical.  Works on local
    and stacked (sharded) stores alike."""
    return dataclasses.replace(
        store,
        index=_I.reindex(store.index, store.n_leaves, store.cfg.max_leaves),
        oflow=jnp.zeros_like(store.oflow),
    )


def scan_resume_sep(store: UruvStore, k1, max_scan_leaves: int, k2):
    """Separator of the first leaf past a ``max_scan_leaves`` window that
    starts at k1's leaf (or ``k2`` when the window reaches the end) — the
    zero-hit resume frontier of the bounded ``scan_page`` pass."""
    i32 = jnp.int32
    bn, bs, _ = _I.descend(store.index, jnp.asarray([k1], i32))
    lo = _I.leaf_ordinal(store.index, bn, bs)[0]
    end_pos = lo + max_scan_leaves
    return jnp.where(
        end_pos < store.n_leaves,
        _I.sep_at(store.index,
                  jnp.minimum(end_pos, jnp.maximum(store.n_leaves - 1, 0))),
        jnp.asarray(k2, i32),
    )


# ---------------------------------------------------------------------------
# Introspection (host-side; tests)
# ---------------------------------------------------------------------------

def directory(store: UruvStore):
    """Host-side flat view of the index: (sep_keys[n_leaves],
    leaf_ids[n_leaves]) numpy arrays in global key order — what the
    flat-directory era materialized eagerly."""
    import numpy as np

    nl = int(np.asarray(store.n_leaves))
    return _I.directory(store.index, nl)


def live_items(store: UruvStore):
    """All (key, latest non-tombstone value); host-side, for tests."""
    import numpy as np

    s = jax.device_get(store)
    out = []
    n_leaves = int(s.n_leaves)
    _, dirl = _I.directory(s.index, n_leaves)
    for p in range(n_leaves):
        lid = int(dirl[p])
        cnt = int(s.leaf_count[lid])
        for j in range(cnt):
            k = int(s.leaf_keys[lid, j])
            vh = int(s.leaf_vhead[lid, j])
            if vh < 0:
                continue
            v = int(s.ver_value[vh])
            if v != TOMBSTONE:
                out.append((k, v))
    return out


def check_invariants(store: UruvStore) -> None:
    """Paper Appendix B invariants + full index coherence. Host-side.

    On top of the leaf-level invariants this verifies the whole fat-node
    index (per-level sortedness, child coverage, spine + reverse-map
    coherence — :func:`repro.core.index.check_index`) and that the
    ``leaf_next`` chain visits exactly the leftmost-descent (in-order)
    leaf sequence.
    """
    import numpy as np

    s = jax.device_get(store)
    nl = int(s.n_leaves)
    assert nl >= 1
    _I.check_index(s.index, nl)
    dirk, dirl = _I.directory(s.index, nl)
    assert dirk[0] == KEY_MIN
    assert np.all(np.diff(dirk.astype(np.int64)) > 0), "separators not sorted"
    prev_last = None
    for p in range(nl):
        lid = int(dirl[p])
        cnt = int(s.leaf_count[lid])
        row = np.asarray(s.leaf_keys[lid])
        assert np.all(row[cnt:] == KEY_MAX), "leaf padding violated"
        if cnt:
            assert np.all(np.diff(row[:cnt].astype(np.int64)) > 0), (
                "invariant 1: leaf not sorted/unique"
            )
            if p > 0:
                assert row[0] >= dirk[p], "leaf underflows its separator"
            if prev_last is not None:
                assert row[0] > prev_last, "invariant 2: inter-leaf order"
            prev_last = row[cnt - 1]
    # the chained leaf level must be EXACTLY the in-order leaf sequence
    # (the paper's linked list under the index; cross-checked after every
    # structural delta and maintenance merge)
    chain = []
    cur = int(dirl[0])
    seen = set()
    while cur != -1 and cur not in seen and len(chain) <= nl:
        chain.append(cur)
        seen.add(cur)
        cur = int(np.asarray(s.leaf_next)[cur])
    assert chain == dirl.tolist(), (
        f"leaf_next chain != leftmost-descent order: {chain} vs "
        f"{dirl.tolist()}"
    )
