"""repro.core.index — the multi-level fat-node internal index (DESIGN.md Sec 11).

The paper's Uruv keeps a balanced search index *installed on the linked
leaf list*, maintained by proactive, LOCAL split/merge.  Earlier PRs
flattened that index into one sorted separator array (``dir_keys`` /
``dir_leaf``) that was fully rebuilt — an O(ML) scatter plus a full
``leaf_next`` rewrite — on every structural batch.  This module restores
the paper's shape, batch-style:

  * **Levels.**  ``node_keys[l][C_l, F]`` / ``node_child[l][C_l, F]`` /
    ``node_cnt[l][C_l]`` — level 0 is the bottom (fat nodes over the leaf
    separators; children are leaf ids), level ``depth-1`` is the root
    (always node id 0).  Entries are sorted in-node and KEY_MAX padded;
    an entry's key is a *lower bound* for its subtree (the leftmost spine
    carries KEY_MIN).  A node id never changes once allocated — order is
    parent-defined, like the paper's pointer structure.
  * **Deltas, not rebuilds.**  Structural batches emit a bounded
    separator delta (one insert per leaf split, one delete per leaf
    merge).  It is applied level-by-level bottom-up: a touched node is
    rewritten in a [2F] workspace; only on *overflow* does it split and
    push one entry to its parent (the paper's proactive balancing,
    batched).  Work is O(touched · F · depth), independent of ML.
  * **Ordinal spine.**  Range scans need rank/select over the global
    leaf order.  ``ord_node`` / ``node_pos`` / ``ord_start`` keep the
    bottom nodes in key order with prefix separator counts — O(C0) =
    O(ML / (F/2)) to refresh, and only when separators or bottom-node
    topology change (a version-only batch touches nothing).
  * **Reverse map.**  ``leaf_ent[leaf_id] = bottom_node * F + slot``
    lets lifecycle relocation retarget a moved leaf with O(1) writes
    instead of the old O(ML) directory remap, and gives maintenance the
    (node, slot) of a merged-away leaf's separator directly.

Capacity discipline: node pools are power-of-two sized from (ML, F)
assuming >= F/2 fill (what splits guarantee).  A batch that cannot place
its delta — pool exhausted by deletion fragmentation, or root overflow —
rejects atomically with ``OFLOW_INDEX`` and the combining layer calls
:func:`reindex`: a stop-the-world repack at 3F/4 fill, the rare analogue
of ``compact()``.  ``lifecycle.grow`` tail-extends every pool (and adds
root levels) under the same pow2 bucketing as the leaf pool.

Layering: this module and ``repro.core.backend`` are the ONLY places
allowed to touch index internals or run searchsorted-style descents
(enforced by a grep gate in scripts/check.sh).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.analysis.marks import device_pass
from repro.core.ref import KEY_MAX

KEY_MIN = -(2**31)      # left sentinel: separator of the leftmost leaf

_I32MAX = KEY_MAX       # int32 max — ord_start padding (keeps searchsorted
                        # monotone); spelled via the blessed sentinel module


def pow2ceil(n: int) -> int:
    return 1 << max(0, int(n) - 1).bit_length()


# ---------------------------------------------------------------------------
# Static shape model
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class IndexConfig:
    """Static index geometry (compile-time constant, derived from the
    store's (max_leaves, index_fanout) — see :func:`index_config`)."""

    fanout: int                 # F — entries per fat node
    depth: int                  # levels; level 0 bottom, depth-1 root
    caps: Tuple[int, ...]       # per-level node-pool capacity (pow2)

    @property
    def pack_fill(self) -> int:
        """Occupancy target for freshly built nodes (3F/4 — slack for
        in-place inserts before the first split)."""
        return max(1, (3 * self.fanout) // 4)


@functools.lru_cache(maxsize=None)
def index_config(max_leaves: int, fanout: int) -> IndexConfig:
    """Depth/capacity model: level l holds the level-(l-1) node stream
    packed at >= F/2 fill (the split guarantee), so caps shrink by F/2
    per level until one root node covers everything."""
    if fanout < 4:
        raise ValueError(f"index_fanout must be >= 4, got {fanout}")
    half = fanout // 2
    caps = []
    n_entries = max(1, int(max_leaves))
    while True:
        n_nodes = -(-n_entries // half)          # ceil under F/2 fill
        caps.append(pow2ceil(n_nodes))
        if n_entries <= fanout:                  # fits one (root) node
            caps[-1] = max(caps[-1], 1)
            break
        n_entries = n_nodes
    # the top level must be a single live node: its cap only needs >= 1,
    # but keep the computed pow2 (slack is harmless and keeps growth
    # monotone in ML)
    return IndexConfig(fanout=fanout, depth=len(caps), caps=tuple(caps))


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class UruvIndex:
    # --- levels (l = 0 bottom .. depth-1 root; root is node 0) ---
    node_keys: Tuple[jax.Array, ...]    # int32 [C_l, F] sorted, KEY_MAX pad
    node_child: Tuple[jax.Array, ...]   # int32 [C_l, F]; l=0: leaf ids
    node_cnt: Tuple[jax.Array, ...]     # int32 [C_l]; 0 == free slot
    # --- ordinal spine over the bottom level ---
    ord_node: jax.Array                 # int32 [C0] ordinal -> node id; -1 pad
    node_pos: jax.Array                 # int32 [C0] node id -> ordinal; -1 dead
    ord_start: jax.Array                # int32 [C0] first leaf ordinal; I32MAX pad
    n_nodes0: jax.Array                 # int32 [] live bottom nodes
    # --- reverse map ---
    leaf_ent: jax.Array                 # int32 [ML] leaf id -> node*F+slot; -1
    # --- observability (cumulative device counters; see api.Uruv.stats) ---
    stat_delta_passes: jax.Array        # int32 [] structural delta passes
    stat_propagations: jax.Array        # int32 [] node updates above level 0
    cfg: IndexConfig = dataclasses.field(metadata=dict(static=True))


def _cummax(x: jax.Array) -> jax.Array:
    return lax.associative_scan(jnp.maximum, x)


# ---------------------------------------------------------------------------
# Build (packed) — create(), compact(), reindex() and checkpoint restore
# ---------------------------------------------------------------------------

def build(cfg: IndexConfig, max_leaves: int, sep_keys: jax.Array,
          sep_leaf: jax.Array, n_sep: jax.Array) -> UruvIndex:
    """Pack ``n_sep`` separators (key order; ``sep_keys[0]`` is the left
    sentinel slot and is forced to KEY_MIN) into fresh fat nodes at
    pack_fill occupancy.  O(ML) — used only at create / compact /
    reindex time; steady-state batches go through the delta path."""
    F, D = cfg.fanout, cfg.depth
    PF = cfg.pack_fill
    i32 = jnp.int32
    ML = max_leaves
    n_sep = jnp.asarray(n_sep, i32)
    sep_keys = jnp.asarray(sep_keys, i32).at[0].set(KEY_MIN)
    sep_leaf = jnp.asarray(sep_leaf, i32)

    keys_t, child_t, cnt_t = [], [], []
    # ---- level 0: separators -> nodes of PF entries.  A depth-1 index
    # IS its root: descent only ever visits node 0, so everything must
    # pack into it (n_sep <= ML <= F there by the depth model). ----
    PF0 = PF if D > 1 else F
    C0 = cfg.caps[0]
    i = jnp.arange(ML, dtype=i32)
    valid = i < n_sep
    node = jnp.where(valid, i // PF0, C0)
    slot = i % PF0
    k0 = jnp.full((C0, F), KEY_MAX, i32).at[node, slot].set(
        jnp.where(valid, sep_keys, KEY_MAX), mode="drop")
    c0 = jnp.full((C0, F), -1, i32).at[node, slot].set(
        jnp.where(valid, sep_leaf, -1), mode="drop")
    n0 = jnp.maximum(-(-n_sep // PF0), 1)
    cnt0 = jnp.clip(n_sep - jnp.arange(C0, dtype=i32) * PF0, 0, PF0)
    cnt0 = jnp.where(jnp.arange(C0) < n0, jnp.maximum(cnt0, 0), 0)
    # an empty store still has its sentinel separator: node 0 keeps >= 1
    cnt0 = cnt0.at[0].max(1)
    keys_t.append(k0)
    child_t.append(c0)
    cnt_t.append(cnt0)

    # ---- upper levels: previous level's node stream, packed ----
    n_prev = n0
    for l in range(1, D):
        Cp = cfg.caps[l - 1]
        Cl = cfg.caps[l]
        j = jnp.arange(Cp, dtype=i32)
        v = j < n_prev
        ekey = jnp.where(v, keys_t[l - 1][:, 0], KEY_MAX)
        pf = PF if l < D - 1 else F          # root swallows everything left
        nd = jnp.where(v, j // pf, Cl)
        sl = j % pf
        kl = jnp.full((Cl, F), KEY_MAX, i32).at[nd, sl].set(
            jnp.where(v, ekey, KEY_MAX), mode="drop")
        cl = jnp.full((Cl, F), -1, i32).at[nd, sl].set(
            jnp.where(v, j, -1), mode="drop")
        nl = jnp.maximum(-(-n_prev // pf), 1)
        cntl = jnp.clip(n_prev - jnp.arange(Cl, dtype=i32) * pf, 0, pf)
        cntl = jnp.where(jnp.arange(Cl) < nl, cntl, 0)
        cntl = cntl.at[0].max(1)
        keys_t.append(kl)
        child_t.append(cl)
        cnt_t.append(cntl)
        n_prev = nl

    # ---- spine ----
    o = jnp.arange(C0, dtype=i32)
    live = o < n0
    ord_node = jnp.where(live, o, -1)
    node_pos = jnp.where(live, o, -1)
    ord_start = jnp.where(live, o * PF0, _I32MAX)
    # ---- reverse map ----
    leaf_ent = jnp.full((ML,), -1, i32).at[
        jnp.where(valid, sep_leaf, ML)
    ].set(jnp.where(valid, node * F + slot, -1), mode="drop")

    return UruvIndex(
        node_keys=tuple(keys_t), node_child=tuple(child_t),
        node_cnt=tuple(cnt_t),
        ord_node=ord_node, node_pos=node_pos, ord_start=ord_start,
        n_nodes0=n0.astype(i32), leaf_ent=leaf_ent,
        stat_delta_passes=jnp.array(0, i32),
        stat_propagations=jnp.array(0, i32),
        cfg=cfg,
    )


# ---------------------------------------------------------------------------
# Descent (XLA formulation; the Pallas twin lives in kernels/uruv_search)
# ---------------------------------------------------------------------------

@device_pass
def descend(idx: UruvIndex, queries: jax.Array):
    """Root->leaf blocked F-way descent.  Returns (bnode, bslot, leaf):
    the bottom (node, slot) of the last separator <= q, and its leaf."""
    bnode, bslot, leaf, _, _ = _descend_full(idx, queries)
    return bnode, bslot, leaf


@device_pass
def descend_path(idx: UruvIndex, queries: jax.Array):
    """Full descent path: (nodes[D, P], slots[D, P]) with level 0 first
    (nodes[0] == bottom node).  XLA-only — the structural delta uses it
    to target parents when a node split propagates."""
    _, _, _, nodes, slots = _descend_full(idx, queries)
    return nodes, slots


@device_pass
def _descend_full(idx: UruvIndex, queries: jax.Array):
    F, D = idx.cfg.fanout, idx.cfg.depth
    i32 = jnp.int32
    q = jnp.asarray(queries, i32)
    cur = jnp.zeros(q.shape, i32)                # root is node 0
    nodes, slots = [None] * D, [None] * D
    slot = jnp.zeros(q.shape, i32)
    for l in range(D - 1, -1, -1):
        rows = idx.node_keys[l][cur]             # [P, F]
        # live entries only: KEY_MAX is padding, never a separator (keeps
        # q == KEY_MAX sentinels — retired range queries — well-defined)
        slot = jnp.maximum(
            jnp.sum(((rows <= q[..., None]) & (rows < KEY_MAX)).astype(i32),
                    axis=-1) - 1, 0)
        nodes[l], slots[l] = cur, slot
        nxt = jnp.take_along_axis(
            idx.node_child[l][cur], slot[..., None], axis=-1)[..., 0]
        if l > 0:
            cur = nxt
    return nodes[0], slots[0], nxt, jnp.stack(nodes), jnp.stack(slots)


# ---------------------------------------------------------------------------
# Rank / select over the ordinal spine
# ---------------------------------------------------------------------------

def leaf_ordinal(idx: UruvIndex, bnode: jax.Array,
                 bslot: jax.Array) -> jax.Array:
    """Global leaf ordinal (the old flat-directory position) of a bottom
    (node, slot) entry."""
    pos = idx.node_pos[jnp.maximum(bnode, 0)]
    return idx.ord_start[jnp.maximum(pos, 0)] + bslot


@device_pass
def rank_right(idx: UruvIndex, queries: jax.Array) -> jax.Array:
    """# separators <= q — the old searchsorted(dir_keys, q, 'right')."""
    bnode, bslot, _ = descend(idx, queries)
    return leaf_ordinal(idx, bnode, bslot) + 1


@device_pass
def ord_locate(idx: UruvIndex, p: jax.Array):
    """Leaf ordinal -> (bottom node, slot).  Caller masks p outside
    [0, n_leaves) — out-of-range ordinals return clamped garbage."""
    C0 = idx.ord_start.shape[-1]
    no = jnp.clip(
        jnp.searchsorted(idx.ord_start, p, side="right").astype(jnp.int32) - 1,
        0, C0 - 1,
    )
    node = idx.ord_node[no]
    slot = p - idx.ord_start[no]
    return jnp.maximum(node, 0), jnp.clip(slot, 0, idx.cfg.fanout - 1)


@device_pass
def leaf_at(idx: UruvIndex, p: jax.Array) -> jax.Array:
    """Leaf id at ordinal p (the old dir_leaf[p]); caller masks range."""
    node, slot = ord_locate(idx, p)
    return idx.node_child[0][node, slot]


@device_pass
def sep_at(idx: UruvIndex, p: jax.Array) -> jax.Array:
    """Separator key at ordinal p (the old dir_keys[p]); caller masks."""
    node, slot = ord_locate(idx, p)
    return idx.node_keys[0][node, slot]


@device_pass(static=("side",))
def rank(a: jax.Array, v: jax.Array, *, side: str = "right") -> jax.Array:
    """Generic sorted-array rank (int32).  The ONE sanctioned searchsorted
    for non-index arrays (worklist offsets, hit cumsums) — keeps the
    scripts/check.sh descent gate greppable."""
    return jnp.searchsorted(a, v, side=side).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Delta application — the tentpole.  Bounded bottom-up separator inserts
# (leaf splits) with overflow-triggered node splits, and separator deletes
# (leaf merges) that never underflow a node to zero.
# ---------------------------------------------------------------------------

def _insert_level(keys_l, child_l, cnt_l, it_node, it_key, it_child,
                  it_gidx, it_valid, *, fanout: int, is_root: bool):
    """Insert up to N=len(it_node) (key, child) entries into level-l nodes.

    Returns (keys_l, child_l, cnt_l, seg ...) where ``seg`` describes the
    per-touched-node outcome: (seg_node, seg_gidx, seg_real, ovf, rid,
    lc, new_cnt, left_keys, left_child, right_keys, right_child) plus the
    emitted parent items (node splits) and an overflow flag.  Invariant
    (guaranteed by construction, guarded anyway): <= F inserts per node.
    """
    F = fanout
    Cl = keys_l.shape[0]
    N = it_node.shape[0]
    W = 2 * F
    i32 = jnp.int32
    posN = jnp.arange(N, dtype=i32)

    # ---- group items by target node (sort by (node, key)) ----
    nodev = jnp.where(it_valid, it_node, Cl)
    snode, skey, schild, sgidx = lax.sort(
        (nodev, it_key, it_child, it_gidx), num_keys=2)
    svalid = snode < Cl
    first = svalid & jnp.concatenate(
        [jnp.ones((1,), bool), snode[1:] != snode[:-1]])
    segid = jnp.cumsum(first.astype(i32)) - 1
    segstart = _cummax(jnp.where(first, posN, -1))
    off = posN - jnp.maximum(segstart, 0)
    n_seg = jnp.sum(first.astype(i32))
    seg_real = posN < n_seg
    srow = jnp.where(first, segid, N - 1)
    seg_node = jnp.zeros((N,), i32).at[srow].set(
        jnp.where(first, snode, 0), mode="drop")
    seg_node = jnp.where(seg_real, seg_node, 0)
    seg_gidx = jnp.zeros((N,), i32).at[srow].set(
        jnp.where(first, sgidx, 0), mode="drop")
    seg_ins = jnp.zeros((N,), i32).at[
        jnp.where(svalid, segid, N - 1)
    ].add(jnp.where(svalid, 1, 0), mode="drop")

    # ---- per-node workspace merge ----
    # Measured on CPU XLA, the row-wise 2-operand lax.sort is the fastest
    # way to merge here (~0.7 ms for [128, 32]): rank-scatter and one-hot
    # matmul formulations both lose to it because XLA CPU scatters are
    # scalarized (~0.2 us per scattered element).
    wk_keys = jnp.full((N, W), KEY_MAX, i32)
    wk_child = jnp.full((N, W), -1, i32)
    wk_keys = wk_keys.at[:, :F].set(
        jnp.where(seg_real[:, None], keys_l[seg_node], KEY_MAX))
    wk_child = wk_child.at[:, :F].set(
        jnp.where(seg_real[:, None], child_l[seg_node], -1))
    row = jnp.where(svalid, segid, N - 1)
    col = jnp.where(svalid, F + jnp.minimum(off, F - 1), W)
    wk_keys = wk_keys.at[row, col].set(
        jnp.where(svalid, skey, KEY_MAX), mode="drop")
    wk_child = wk_child.at[row, col].set(
        jnp.where(svalid, schild, -1), mode="drop")
    wk_keys, wk_child = lax.sort((wk_keys, wk_child), dimension=1, num_keys=1)

    old_cnt = jnp.where(seg_real, cnt_l[seg_node], 0)
    new_cnt = old_cnt + seg_ins
    oflow = jnp.any(seg_ins > F)             # structural bound violated

    # ---- node splits on overflow ----
    ovf = seg_real & (new_cnt > F)
    lc = jnp.where(ovf, (new_cnt + 1) // 2, new_cnt)
    free = cnt_l == 0
    free_cum = jnp.cumsum(free.astype(i32))      # [C_l] (vectorized)
    n_free = free_cum[Cl - 1]
    ovfrank = jnp.cumsum(ovf.astype(i32)) - 1
    n_ovf = jnp.sum(ovf.astype(i32))
    if is_root:
        oflow |= n_ovf > 0                   # the root may never split
    oflow |= n_ovf > n_free
    # k-th free slot by binary search over the free-count prefix — an
    # O(N log C) gather instead of an O(C) scatter (CPU XLA scatters are
    # scalarized; this keeps the delta pass independent of the pool size)
    rid_k = jnp.searchsorted(
        free_cum, jnp.minimum(ovfrank, N - 1) + 1, side="left").astype(i32)
    rid = jnp.where(ovf, jnp.minimum(rid_k, Cl - 1), Cl)

    colW = jnp.arange(W, dtype=i32)[None, :]
    lmask = colW < lc[:, None]
    lk = jnp.where(lmask, wk_keys, KEY_MAX)[:, :F]
    lch = jnp.where(lmask, wk_child, -1)[:, :F]
    shift = jnp.minimum(colW + lc[:, None], W - 1)
    rk_full = jnp.take_along_axis(wk_keys, shift, axis=1)
    rch_full = jnp.take_along_axis(wk_child, shift, axis=1)
    rmask = colW < (new_cnt - lc)[:, None]
    rk = jnp.where(rmask, rk_full, KEY_MAX)[:, :F]
    rch = jnp.where(rmask, rch_full, -1)[:, :F]

    wnode = jnp.where(seg_real, seg_node, Cl)
    keys_l = keys_l.at[wnode].set(lk, mode="drop")
    child_l = child_l.at[wnode].set(lch, mode="drop")
    cnt_l = cnt_l.at[wnode].set(lc, mode="drop")
    wrid = jnp.where(ovf & ~oflow, rid, Cl)   # don't scribble when rejecting
    keys_l = keys_l.at[wrid].set(rk, mode="drop")
    child_l = child_l.at[wrid].set(rch, mode="drop")
    cnt_l = cnt_l.at[wrid].set(new_cnt - lc, mode="drop")

    # ---- emitted parent items: (right half's first key, right node id) ----
    em_key = rk[:, 0]
    em_child = rid
    em_valid = ovf & ~oflow
    seg = dict(node=seg_node, gidx=seg_gidx, real=seg_real, ovf=ovf,
               rid=rid, lc=lc, new_cnt=new_cnt,
               lk=lk, lch=lch, rk=rk, rch=rch)
    return (keys_l, child_l, cnt_l, seg,
            em_key, em_child, em_valid, oflow)


def _maybe_insert_level(keys_l, child_l, cnt_l, it_node, it_key, it_child,
                        it_gidx, it_valid, *, fanout: int, is_root: bool):
    """:func:`_insert_level` under a lax.cond: a level with no incoming
    items (the common case above level 1 — splits propagate only on
    overflow) costs one predicate instead of a full workspace pass, so
    the delta stays O(*touched* levels) at runtime, not O(depth)."""
    F = fanout
    Cl = keys_l.shape[0]
    N = it_node.shape[0]
    i32 = jnp.int32

    def live(args):
        return _insert_level(*args, fanout=fanout, is_root=is_root)

    def skip(args):
        keys_l, child_l, cnt_l, *_ = args
        z = jnp.zeros((N,), i32)
        zb = jnp.zeros((N,), bool)
        seg = dict(node=z, gidx=z, real=zb, ovf=zb,
                   rid=jnp.full((N,), Cl, i32), lc=z, new_cnt=z,
                   lk=jnp.full((N, F), KEY_MAX, i32),
                   lch=jnp.full((N, F), -1, i32),
                   rk=jnp.full((N, F), KEY_MAX, i32),
                   rch=jnp.full((N, F), -1, i32))
        return (keys_l, child_l, cnt_l, seg,
                jnp.full((N,), KEY_MAX, i32), jnp.full((N,), Cl, i32), zb,
                jnp.zeros((), bool))

    return lax.cond(
        jnp.any(it_valid), live, skip,
        (keys_l, child_l, cnt_l, it_node, it_key, it_child, it_gidx,
         it_valid))


@device_pass
def apply_split_delta(idx: UruvIndex, valid: jax.Array, gkey: jax.Array,
                      old_leaf: jax.Array, left_id: jax.Array,
                      right_id: jax.Array, rkey: jax.Array):
    """Apply one structural batch's leaf-split delta.

    Per split group g (masked by ``valid``): the leaf ``old_leaf[g]``
    (whose range contains ``gkey[g]``) froze and split into (left_id,
    right_id) at separator ``rkey[g]`` — its bottom entry is retargeted
    to ``left_id`` and (rkey, right_id) is inserted, propagating node
    splits upward only on overflow.  Returns ``(index, oflow)``; on
    oflow the caller rejects the whole batch (the returned index must be
    discarded).
    """
    cfg = idx.cfg
    F, D = cfg.fanout, cfg.depth
    i32 = jnp.int32
    P = gkey.shape[0]
    ML = idx.leaf_ent.shape[0]
    path_nodes, path_slots = descend_path(idx, gkey)     # [D, P]
    bnode = jnp.where(valid, path_nodes[0], cfg.caps[0])
    bslot = jnp.where(valid, path_slots[0], F)

    keys_t = list(idx.node_keys)
    child_t = list(idx.node_child)
    cnt_t = list(idx.node_cnt)

    # level 0 entry retarget: old (frozen) leaf -> left half
    child_t[0] = child_t[0].at[bnode, bslot].set(
        jnp.where(valid, left_id, -1), mode="drop")
    leaf_ent = idx.leaf_ent.at[jnp.where(valid, old_leaf, ML)].set(
        -1, mode="drop")

    it_node = jnp.where(valid, bnode, cfg.caps[0])
    it_key = rkey
    it_child = right_id
    it_gidx = jnp.arange(P, dtype=i32)
    it_valid = valid
    oflow = jnp.zeros((), bool)
    seg0 = None
    props = jnp.zeros((), i32)
    for l in range(D):
        (keys_t[l], child_t[l], cnt_t[l], seg,
         em_key, em_child, em_valid, ofl) = _maybe_insert_level(
            keys_t[l], child_t[l], cnt_t[l],
            it_node, it_key, it_child, it_gidx, it_valid,
            fanout=F, is_root=(l == D - 1))
        oflow |= ofl
        if l == 0:
            seg0 = seg
        else:
            props += jnp.sum(it_valid.astype(i32))
        if l + 1 < D:
            # parent of a split level-l node = the descent path of any
            # item that targeted it (paths to a node are unique)
            parent = path_nodes[l + 1][seg["gidx"]]
            it_node = jnp.where(em_valid, parent, cfg.caps[l + 1])
            it_key, it_child = em_key, em_child
            it_gidx = seg["gidx"]
            it_valid = em_valid

    # ---- reverse map: rewrite leaf_ent for every touched bottom node ----
    ML = leaf_ent.shape[0]
    sl = jnp.arange(F, dtype=i32)[None, :]
    lmask = seg0["real"][:, None] & (sl < seg0["lc"][:, None])
    leaf_ent = leaf_ent.at[jnp.where(lmask, seg0["lch"], ML)].set(
        jnp.where(lmask, seg0["node"][:, None] * F + sl, -1), mode="drop")
    rmask = (seg0["ovf"] & ~oflow)[:, None] & (
        sl < (seg0["new_cnt"] - seg0["lc"])[:, None])
    leaf_ent = leaf_ent.at[jnp.where(rmask, seg0["rch"], ML)].set(
        jnp.where(rmask, seg0["rid"][:, None] * F + sl, -1), mode="drop")

    # ---- spine refresh: insert split-off nodes after their left halves.
    # Gather-formulated (searchsorted over the K sorted insertion points +
    # one K-index scatter): CPU XLA scatters are scalarized, so an O(C0)
    # index scatter here would make the delta pass scale with the pool —
    # this keeps it O(C0) *vectorized* work + O(K) scattered elements. ----
    C0 = cfg.caps[0]
    o = jnp.arange(C0, dtype=i32)
    n_split0 = jnp.sum(seg0["ovf"].astype(i32))
    n0 = idx.n_nodes0 + n_split0
    # old ordinals of the split (left) nodes, sorted, with their new
    # right-half ids riding along
    sp = jnp.where(seg0["ovf"], idx.node_pos[seg0["node"]], _I32MAX)
    sps, srids = lax.sort((sp, seg0["rid"]), num_keys=1)
    ins_newpos = jnp.where(
        sps < _I32MAX, sps + jnp.arange(P, dtype=i32) + 1, _I32MAX)
    kk = jnp.searchsorted(ins_newpos, o, side="right").astype(i32)
    is_ins = (kk > 0) & (
        ins_newpos[jnp.maximum(kk - 1, 0)] == o)
    src = jnp.clip(o - kk, 0, C0 - 1)
    ord_node = jnp.where(
        is_ins,
        srids[jnp.maximum(kk - 1, 0)],
        jnp.where(o - kk < idx.n_nodes0, idx.ord_node[src], -1),
    )
    ord_node = jnp.where(o < n0, ord_node, -1)
    # inverse: every old node shifts by the insertions before it; the K
    # new nodes land right after their left halves (one small scatter)
    p_n = idx.node_pos
    shift = jnp.searchsorted(sps, jnp.maximum(p_n, 0),
                             side="left").astype(i32)
    node_pos = jnp.where(p_n >= 0, p_n + shift, -1)
    newpos_k = jnp.maximum(sp, 0) + jnp.searchsorted(
        sps, jnp.maximum(sp, 0), side="left").astype(i32) + 1
    node_pos = node_pos.at[
        jnp.where(seg0["ovf"], seg0["rid"], C0)
    ].set(jnp.where(seg0["ovf"], newpos_k, -1), mode="drop")
    ord_cnt = jnp.where(o < n0, cnt_t[0][jnp.maximum(ord_node, 0)], 0)
    ord_start = jnp.cumsum(ord_cnt) - ord_cnt
    ord_start = jnp.where(o < n0, ord_start, _I32MAX).astype(i32)

    new = dataclasses.replace(
        idx,
        node_keys=tuple(keys_t), node_child=tuple(child_t),
        node_cnt=tuple(cnt_t),
        ord_node=ord_node, node_pos=node_pos, ord_start=ord_start,
        n_nodes0=n0.astype(i32), leaf_ent=leaf_ent,
        stat_delta_passes=idx.stat_delta_passes + 1,
        stat_propagations=idx.stat_propagations + props,
    )
    return new, oflow


def merge_deletable(idx: UruvIndex, ord_del: jax.Array) -> jax.Array:
    """True where the separator at ordinal ``ord_del`` may be deleted by
    a leaf merge: it must NOT be slot 0 of its bottom node (entry keys
    are subtree lower bounds — dropping a node's first entry would break
    descent).  Skipped pairs become eligible again after a reindex."""
    _, slot = ord_locate(idx, ord_del)
    return slot >= 1


@device_pass
def apply_merge_delta(idx: UruvIndex, ord_del: jax.Array, lb: jax.Array,
                      valid: jax.Array) -> UruvIndex:
    """Delete the separators at ordinals ``ord_del`` (the right members of
    merged leaf pairs; ``lb`` their leaf ids).  Caller guarantees each is
    at slot >= 1 of its bottom node (see :func:`merge_deletable`), so no
    node empties and nothing propagates.  O(budget · F)."""
    cfg = idx.cfg
    F = cfg.fanout
    C0 = cfg.caps[0]
    i32 = jnp.int32
    node, slot = ord_locate(idx, ord_del)
    node = jnp.where(valid, node, C0)
    keys0 = idx.node_keys[0].at[node, jnp.where(valid, slot, F)].set(
        KEY_MAX, mode="drop")
    child0 = idx.node_child[0].at[node, jnp.where(valid, slot, F)].set(
        -1, mode="drop")
    # compact the touched rows sort-free: surviving entries (key <
    # KEY_MAX) keep their relative order, their new position is the
    # count of survivors before them (duplicate gathers of a shared node
    # scatter identical rows — deterministic)
    gnode = jnp.where(valid, node, 0)
    rk = keys0[gnode]                       # [B, F]
    rch = child0[gnode]
    live_e = rk < KEY_MAX
    newpos = jnp.cumsum(live_e.astype(i32), axis=1) - live_e.astype(i32)
    rowsB = jnp.arange(rk.shape[0], dtype=i32)[:, None]
    ck = jnp.full(rk.shape, KEY_MAX, i32).at[
        rowsB, jnp.where(live_e, newpos, F)].set(rk, mode="drop")
    cch = jnp.full(rk.shape, -1, i32).at[
        rowsB, jnp.where(live_e, newpos, F)].set(rch, mode="drop")
    keys0 = keys0.at[node].set(ck, mode="drop")
    child0 = child0.at[node].set(cch, mode="drop")
    rk, rch = ck, cch
    dcnt = jnp.zeros((C0,), i32).at[node].add(
        jnp.where(valid, 1, 0), mode="drop")
    cnt0 = idx.node_cnt[0] - dcnt

    # reverse map: cleared leaves out, shifted survivors rewritten
    ML = idx.leaf_ent.shape[0]
    leaf_ent = idx.leaf_ent.at[jnp.where(valid, lb, ML)].set(-1, mode="drop")
    sl = jnp.arange(F, dtype=i32)[None, :]
    tmask = valid[:, None] & (sl < cnt0[gnode][:, None])
    leaf_ent = leaf_ent.at[jnp.where(tmask, rch, ML)].set(
        jnp.where(tmask, gnode[:, None] * F + sl, -1), mode="drop")

    # spine: counts changed -> prefix refresh (node set unchanged)
    o = jnp.arange(C0, dtype=i32)
    liveo = o < idx.n_nodes0
    ord_cnt = jnp.where(liveo, cnt0[jnp.maximum(idx.ord_node, 0)], 0)
    ord_start = jnp.cumsum(ord_cnt) - ord_cnt
    ord_start = jnp.where(liveo, ord_start, _I32MAX).astype(i32)

    return dataclasses.replace(
        idx,
        node_keys=(keys0,) + idx.node_keys[1:],
        node_child=(child0,) + idx.node_child[1:],
        node_cnt=(cnt0,) + idx.node_cnt[1:],
        ord_start=ord_start, leaf_ent=leaf_ent,
    )


@device_pass
def retarget_leaves(idx: UruvIndex, src: jax.Array, dst: jax.Array,
                    valid: jax.Array) -> UruvIndex:
    """Point the bottom entries of relocated leaves at their new ids
    (lifecycle relocation: ``src -> dst``).  O(budget) scatters via the
    reverse map — the old path remapped the whole O(ML) directory."""
    F = idx.cfg.fanout
    ML = idx.leaf_ent.shape[0]
    ent = idx.leaf_ent[jnp.where(valid, src, 0)]
    node = jnp.where(valid & (ent >= 0), ent // F, idx.cfg.caps[0])
    slot = jnp.clip(ent % F, 0, F - 1)
    child0 = idx.node_child[0].at[node, slot].set(
        jnp.where(valid, dst, -1), mode="drop")
    leaf_ent = idx.leaf_ent.at[jnp.where(valid, src, ML)].set(-1, mode="drop")
    leaf_ent = leaf_ent.at[jnp.where(valid, dst, ML)].set(ent, mode="drop")
    return dataclasses.replace(
        idx,
        node_child=(child0,) + idx.node_child[1:],
        leaf_ent=leaf_ent,
    )


# ---------------------------------------------------------------------------
# Reindex (stop-the-world repack) + growth
# ---------------------------------------------------------------------------

def inorder(idx: UruvIndex, max_leaves: int):
    """(sep_keys[ML], sep_leaf[ML]) in global key order, KEY_MAX / -1
    padded — the flat-directory view, materialized on demand."""
    p = jnp.arange(max_leaves, dtype=jnp.int32)
    keys = sep_at(idx, p)
    leaves = leaf_at(idx, p)
    return keys, leaves


@functools.partial(jax.jit, static_argnames=("max_leaves",))
def _reindex(idx: UruvIndex, n_sep: jax.Array, *, max_leaves: int):
    keys, leaves = inorder(idx, max_leaves)
    valid = jnp.arange(max_leaves) < n_sep
    keys = jnp.where(valid, keys, KEY_MAX)
    leaves = jnp.where(valid, leaves, -1)
    new = build(idx.cfg, max_leaves, keys, leaves, n_sep)
    return dataclasses.replace(
        new,
        stat_delta_passes=idx.stat_delta_passes,
        stat_propagations=idx.stat_propagations,
    )


def reindex(idx: UruvIndex, n_sep: jax.Array, max_leaves: int) -> UruvIndex:
    """Rebuild the index from its own in-order traversal, repacked at
    pack_fill.  The recovery path for ``OFLOW_INDEX`` (fragmentation) —
    O(ML), stop-the-world, rare; results are unchanged by construction.
    Works on stacked (sharded) stores via vmap (same shapes per shard)."""
    import numpy as np
    if np.asarray(n_sep).ndim:
        return jax.vmap(
            lambda ix, n: _reindex(ix, n, max_leaves=max_leaves)
        )(idx, n_sep)
    return _reindex(idx, n_sep, max_leaves=max_leaves)


def grow_to(idx: UruvIndex, new_cfg: IndexConfig, new_ml: int) -> UruvIndex:
    """Tail-extend every node pool to ``new_cfg`` capacities (same pow2
    discipline as lifecycle.grow) and stack fresh root levels when the
    depth grows.  Node ids, spine ordinals and every entry are preserved
    bit-exactly — pools extend at the tail, nothing moves."""
    F = new_cfg.fanout
    i32 = jnp.int32
    assert new_cfg.depth >= idx.cfg.depth

    def pad_rows(x, cap, fill):
        extra = cap - x.shape[-2]
        if extra == 0:
            return x
        shape = x.shape[:-2] + (extra, x.shape[-1])
        return jnp.concatenate([x, jnp.full(shape, fill, x.dtype)], axis=-2)

    def pad_vec(x, cap, fill):
        extra = cap - x.shape[-1]
        if extra == 0:
            return x
        shape = x.shape[:-1] + (extra,)
        return jnp.concatenate([x, jnp.full(shape, fill, x.dtype)], axis=-1)

    lead = idx.ord_node.shape[:-1]            # stacked (sharded) batch dims
    keys_t, child_t, cnt_t = [], [], []
    for l in range(idx.cfg.depth):
        keys_t.append(pad_rows(idx.node_keys[l], new_cfg.caps[l], KEY_MAX))
        child_t.append(pad_rows(idx.node_child[l], new_cfg.caps[l], -1))
        cnt_t.append(pad_vec(idx.node_cnt[l], new_cfg.caps[l], 0))
    for l in range(idx.cfg.depth, new_cfg.depth):
        Cl = new_cfg.caps[l]
        k = jnp.full(lead + (Cl, F), KEY_MAX, i32).at[..., 0, 0].set(KEY_MIN)
        c = jnp.full(lead + (Cl, F), -1, i32).at[..., 0, 0].set(0)
        n = jnp.zeros(lead + (Cl,), i32).at[..., 0].set(1)
        keys_t.append(k)
        child_t.append(c)
        cnt_t.append(n)
    return dataclasses.replace(
        idx,
        node_keys=tuple(keys_t), node_child=tuple(child_t),
        node_cnt=tuple(cnt_t),
        ord_node=pad_vec(idx.ord_node, new_cfg.caps[0], -1),
        node_pos=pad_vec(idx.node_pos, new_cfg.caps[0], -1),
        ord_start=pad_vec(idx.ord_start, new_cfg.caps[0], _I32MAX),
        leaf_ent=pad_vec(idx.leaf_ent, new_ml, -1),
        cfg=new_cfg,
    )


# ---------------------------------------------------------------------------
# Host-side introspection + invariants (tests, check_invariants)
# ---------------------------------------------------------------------------

def directory(idx: UruvIndex, n_sep: int):
    """Host-side flat view: (sep_keys[n_sep], sep_leaf[n_sep]) numpy."""
    import numpy as np
    keys, leaves = inorder(idx, idx.leaf_ent.shape[-1])
    return (np.asarray(keys)[:n_sep], np.asarray(leaves)[:n_sep])


def check_index(idx: UruvIndex, n_sep: int) -> None:
    """Full index verification (host-side; see store.check_invariants):

      * per-level in-node sortedness + KEY_MAX padding + cnt coherence
      * child coverage: the root's in-order expansion visits every live
        node exactly once; entry keys equal their child's first key as a
        lower bound (<=), strictly increasing globally
      * spine coherence: ord_node/node_pos inverse bijection, ord_start
        exact prefix sums, n_nodes0 == live bottom nodes
      * reverse map: leaf_ent is the exact inverse of bottom child slots
    """
    import numpy as np

    ix = jax.device_get(idx)
    cfg = ix.cfg
    F, D = cfg.fanout, cfg.depth
    for l in range(D):
        k = np.asarray(ix.node_keys[l])
        c = np.asarray(ix.node_cnt[l])
        assert k.shape == (cfg.caps[l], F)
        for n in range(cfg.caps[l]):
            cnt = int(c[n])
            assert 0 <= cnt <= F, (l, n, cnt)
            row = k[n]
            assert np.all(row[cnt:] == KEY_MAX), f"pad violated l{l} n{n}"
            if cnt:
                assert np.all(np.diff(row[:cnt].astype(np.int64)) > 0), \
                    f"node not sorted l{l} n{n}"

    # in-order expansion from the root
    def expand(l, n):
        cnt = int(ix.node_cnt[l][n])
        assert cnt >= 1, f"empty live node l{l} n{n}"
        out = []
        for s in range(cnt):
            key = int(ix.node_keys[l][n][s])
            ch = int(ix.node_child[l][n][s])
            if l == 0:
                out.append((key, ch, n, s))
            else:
                sub = expand(l - 1, ch)
                assert sub[0][0] >= key, \
                    f"entry key not a lower bound l{l} n{n} s{s}"
                out.extend(sub)
        return out

    flat = expand(D - 1, 0)
    assert len(flat) == n_sep, (len(flat), n_sep)
    keys = [e[0] for e in flat]
    assert keys[0] == KEY_MIN, "left sentinel lost"
    assert all(keys[i] < keys[i + 1] for i in range(len(keys) - 1)), \
        "separators not strictly sorted"

    # spine
    bnodes = []
    for (_, _, n, s) in flat:
        if not bnodes or bnodes[-1] != n:
            bnodes.append(n)
    n0 = int(ix.n_nodes0)
    assert n0 == len(bnodes), (n0, len(bnodes))
    ordn = np.asarray(ix.ord_node)
    npos = np.asarray(ix.node_pos)
    osta = np.asarray(ix.ord_start)
    assert ordn[:n0].tolist() == bnodes, "ord_node order broken"
    assert np.all(ordn[n0:] == -1)
    start = 0
    for p, n in enumerate(bnodes):
        assert int(npos[n]) == p, "node_pos inverse broken"
        assert int(osta[p]) == start, (p, int(osta[p]), start)
        start += int(ix.node_cnt[0][n])
    assert np.all(osta[n0:] == _I32MAX)

    # reverse map
    ent = np.asarray(ix.leaf_ent)
    seen = {}
    for (_, leaf, n, s) in flat:
        assert int(ent[leaf]) == n * F + s, \
            f"leaf_ent broken for leaf {leaf}"
        seen[leaf] = True
    for leaf in range(ent.shape[0]):
        if leaf not in seen:
            assert int(ent[leaf]) == -1, f"stale leaf_ent[{leaf}]"
