"""Non-versioned baseline store — the comparison point for the paper's figures.

The paper benchmarks Uruv against structures without linearizable range
search (LF-B+Tree [5], OpenBw-Tree [23]) and against VCAS-BST [24].  On TPU
we keep two baselines:

  * ``FlatStore`` (this module) — a contiguous sorted array ("fat chunk"
    memory layout in the spirit of Braginsky-Petrank chunks): every batch
    merges into the whole array, O(n) data movement per update batch, and
    range queries read the *latest* values (NOT linearizable under
    interleaved updates).
  * scan-validate-retry range search (`range_query_validated`) — the
    multi-scan technique of Brown & Avni [7] the paper calls out as scaling
    poorly: scan twice, retry until two consecutive scans agree.

Benchmarks (benchmarks/paper_figures.py) reproduce the paper's qualitative
claims: Uruv's localized leaf updates beat O(n) chunk rebuilds as n grows,
and snapshot scans beat validate-retry as update rates grow.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from repro.core.ref import KEY_MAX, NOT_FOUND, TOMBSTONE


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class FlatStore:
    keys: jax.Array     # int32 [N], sorted, KEY_MAX padded
    vals: jax.Array     # int32 [N]
    count: jax.Array    # int32 []
    capacity: int = dataclasses.field(metadata=dict(static=True))


def create(capacity: int = 1 << 16) -> FlatStore:
    return FlatStore(
        keys=jnp.full((capacity,), KEY_MAX, jnp.int32),
        vals=jnp.full((capacity,), NOT_FOUND, jnp.int32),
        count=jnp.array(0, jnp.int32),
        capacity=capacity,
    )


@jax.jit
def bulk_update(store: FlatStore, keys: jax.Array, values: jax.Array) -> FlatStore:
    """Merge a batch (INSERT, or DELETE via TOMBSTONE) — O(n + P) rebuild."""
    P = keys.shape[0]
    N = store.capacity
    # concatenate old + new with new entries winning ties (later rank wins)
    rank_old = jnp.arange(N, dtype=jnp.int32)
    rank_new = N + jnp.arange(P, dtype=jnp.int32)
    all_keys = jnp.concatenate([store.keys, keys])
    all_vals = jnp.concatenate([store.vals, values])
    all_rank = jnp.concatenate([rank_old, rank_new])
    sk, sr, sv = lax.sort((all_keys, all_rank, all_vals), num_keys=2)
    # keep the LAST entry of each key group; drop tombstones
    last = jnp.concatenate([sk[1:] != sk[:-1], jnp.ones((1,), bool)])
    keep = last & (sk < KEY_MAX) & (sv != TOMBSTONE)
    order = jnp.argsort(jnp.where(keep, 0, 1).astype(jnp.int32), stable=True)
    ck = jnp.where(keep[order], sk[order], KEY_MAX)[:N]
    cv = jnp.where(keep[order], sv[order], NOT_FOUND)[:N]
    return FlatStore(ck, cv, jnp.sum(keep.astype(jnp.int32)), store.capacity)


@jax.jit
def bulk_lookup(store: FlatStore, keys: jax.Array) -> jax.Array:
    pos = jnp.searchsorted(store.keys, keys).astype(jnp.int32)
    pos_c = jnp.minimum(pos, store.capacity - 1)
    hit = store.keys[pos_c] == keys
    return jnp.where(hit & (keys < KEY_MAX), store.vals[pos_c], NOT_FOUND)


@functools.partial(jax.jit, static_argnames=("max_results",))
def range_scan(store: FlatStore, k1, k2, *, max_results: int = 1024):
    """Single unvalidated scan of latest values (not linearizable)."""
    lo = jnp.searchsorted(store.keys, k1).astype(jnp.int32)
    idx = lo + jnp.arange(max_results, dtype=jnp.int32)
    idx_c = jnp.minimum(idx, store.capacity - 1)
    k = store.keys[idx_c]
    ok = (idx < store.count) & (k <= k2)
    keys = jnp.where(ok, k, KEY_MAX)
    vals = jnp.where(ok, store.vals[idx_c], NOT_FOUND)
    return keys, vals, jnp.sum(ok.astype(jnp.int32))


def range_query_validated(
    store_ref, k1: int, k2: int, *, max_results: int = 1024, max_retries: int = 16
) -> Tuple[List[Tuple[int, int]], int]:
    """Brown-Avni style multi-scan: retry until two scans agree.

    ``store_ref`` is a zero-arg callable returning the *current* FlatStore
    (emulating a shared pointer under concurrent updates).  Returns
    (results, n_scans).  Under a quiescent store this is 2 scans; under
    heavy interleaved updates it retries — the cost the paper's MVCC design
    avoids.
    """
    prev = None
    for attempt in range(max_retries):
        k, v, c = range_scan(store_ref(), k1, k2, max_results=max_results)
        cur = list(zip(np.asarray(k)[: int(c)].tolist(), np.asarray(v)[: int(c)].tolist()))
        if prev is not None and cur == prev:
            return cur, attempt + 1
        prev = cur
    return prev, max_retries
