"""xLSTM: mLSTM (matrix memory, chunkwise-parallel) + sLSTM (scalar memory,
recurrent) blocks, grouped m:1 (paper's xLSTM[7:1]).

The mLSTM update  C_t = f_t C_{t-1} + i_t v_t k_t^T,  n_t = f_t n_{t-1} + i_t k_t,
h_t = (C_t q_t) / max(|n_t . q_t|, 1)  has exactly the SSD algebra, so
training reuses ``mamba2.ssd_chunked`` (decay = f, u = i·v, B = k, C = q) —
one chunked kernel serves both SSM families (DESIGN.md Sec 6).  Gates use
sigmoid rather than exponential-with-stabilizer (noted simplification).

The sLSTM recurrence is nonlinear (h feeds back through R) and cannot be
parallelized over time; it runs as a lax.scan over steps — the paper's
reason to keep sLSTM blocks rare (1 in 8).

d_ff = 0 in the assigned config: blocks carry their own up/down projections,
there is no separate FFN.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ArchConfig
from repro.distributed.ctx import shard_act
from repro.models import common
from repro.models.mamba2 import ssd_chunked, ssd_recurrent_step


def _dims(cfg: ArchConfig):
    x = cfg.xlstm
    di = int(x.proj_factor * cfg.d_model)
    nh = max(1, di // x.head_dim)
    hd = di // nh
    return di, nh, hd


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------

def init_mlstm(cfg: ArchConfig, key) -> Dict:
    x = cfg.xlstm
    d = cfg.d_model
    di, nh, hd = _dims(cfg)
    pdt = common.dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 7)
    s = 0.02
    return {
        "ln": common.init_norm(cfg, d),
        "up": jax.random.normal(ks[0], (d, 2 * di), pdt) * s,
        "conv_w": jax.random.normal(ks[1], (x.d_conv, di), pdt) * 0.2,
        "conv_b": jnp.zeros((di,), pdt),
        "wq": jax.random.normal(ks[2], (di, nh, hd), pdt) * s,
        "wk": jax.random.normal(ks[3], (di, nh, hd), pdt) * s,
        "wv": jax.random.normal(ks[4], (di, nh, hd), pdt) * s,
        "w_if": jax.random.normal(ks[5], (di, nh, 2), jnp.float32) * s,
        "b_if": jnp.concatenate(
            [jnp.zeros((nh, 1)), jnp.full((nh, 1), 3.0)], axis=1
        ),  # forget-gate bias ~ +3 (long memory at init)
        "out_norm": common.init_norm(cfg, di),
        "down": jax.random.normal(ks[6], (di, d), pdt)
        * s / max(1, cfg.n_layers) ** 0.5,
    }


def _conv_causal(xbc, w, b, S):
    K = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + S, :] * w[i][None, None, :] for i in range(K))
    return jax.nn.silu(out + b)


def mlstm_fwd(cfg: ArchConfig, p: Dict, x: jax.Array) -> jax.Array:
    cdt = common.dtype_of(cfg.compute_dtype)
    B, S, D = x.shape
    di, nh, hd = _dims(cfg)
    h = common.apply_norm(cfg, p["ln"], x).astype(cdt)
    up = h @ p["up"].astype(cdt)
    main, gate = up[..., :di], up[..., di:]
    c = _conv_causal(main, p["conv_w"].astype(cdt), p["conv_b"].astype(cdt), S)
    q = jnp.einsum("bsd,dhk->bshk", c, p["wq"].astype(cdt))
    k = jnp.einsum("bsd,dhk->bshk", c, p["wk"].astype(cdt)) / (hd ** 0.5)
    v = jnp.einsum("bsd,dhk->bshk", main, p["wv"].astype(cdt))
    gif = jnp.einsum(
        "bsd,dhg->bshg", c.astype(jnp.float32), p["w_if"]
    ) + p["b_if"]
    ig = jax.nn.sigmoid(gif[..., 0])                       # [B,S,nh]
    fg = jax.nn.sigmoid(gif[..., 1])

    u = v * ig[..., None].astype(v.dtype)        # stays bf16 (iteration 4)
    chunk = 256
    # fused normalizer: run ONE ssd pass with the normalizer as an extra
    # P-column (u' = [u | i]) — the [B,nc,H,Q,Q] decay/score tensors are the
    # dominant HBM traffic and were previously built twice
    # (EXPERIMENTS.md §Perf iteration 3b)
    u_aug = jnp.concatenate([u, ig[..., None].astype(u.dtype)], axis=-1)
    y_aug, _ = ssd_chunked(u_aug, fg, k, q, chunk)
    y, yn = y_aug[..., :hd], y_aug[..., hd:]
    y = y / jnp.maximum(jnp.abs(yn), 1.0)
    y = y.reshape(B, S, di).astype(cdt) * jax.nn.silu(gate)
    y = common.apply_norm(cfg, p["out_norm"], y)
    return x + (y @ p["down"].astype(cdt)).astype(x.dtype)


def init_mlstm_state(cfg: ArchConfig, B: int):
    x = cfg.xlstm
    di, nh, hd = _dims(cfg)
    return {
        "C": jnp.zeros((B, nh, hd, hd), jnp.float32),
        "n": jnp.zeros((B, nh, 1, hd), jnp.float32),
        "conv": jnp.zeros((B, x.d_conv - 1, di), jnp.float32),
    }


def mlstm_decode(cfg: ArchConfig, p: Dict, x: jax.Array, st: Dict):
    cdt = common.dtype_of(cfg.compute_dtype)
    B = x.shape[0]
    di, nh, hd = _dims(cfg)
    h = common.apply_norm(cfg, p["ln"], x).astype(cdt)
    up = (h @ p["up"].astype(cdt))[:, 0]
    main, gate = up[..., :di], up[..., di:]
    hist = jnp.concatenate(
        [st["conv"], main[:, None, :].astype(jnp.float32)], axis=1
    )
    c = jnp.einsum("bkc,kc->bc", hist, p["conv_w"].astype(jnp.float32))
    c = jax.nn.silu(c + p["conv_b"].astype(jnp.float32))
    q = jnp.einsum("bd,dhk->bhk", c, p["wq"].astype(jnp.float32))
    k = jnp.einsum("bd,dhk->bhk", c, p["wk"].astype(jnp.float32)) / (hd ** 0.5)
    v = jnp.einsum("bd,dhk->bhk", main.astype(jnp.float32), p["wv"].astype(jnp.float32))
    gif = jnp.einsum("bd,dhg->bhg", c, p["w_if"]) + p["b_if"]
    ig = jax.nn.sigmoid(gif[..., 0])
    fg = jax.nn.sigmoid(gif[..., 1])
    C, yC = ssd_recurrent_step(st["C"], v * ig[..., None], fg, k, q)
    n, yn = ssd_recurrent_step(st["n"], ig[..., None], fg, k, q)
    y = yC / jnp.maximum(jnp.abs(yn), 1.0)
    y = y.reshape(B, di).astype(cdt) * jax.nn.silu(gate)
    y = common.apply_norm(cfg, p["out_norm"], y)
    out = x + (y @ p["down"].astype(cdt))[:, None, :].astype(x.dtype)
    return out, {"C": C, "n": n, "conv": hist[:, 1:, :]}


# ---------------------------------------------------------------------------
# sLSTM block
# ---------------------------------------------------------------------------

def init_slstm(cfg: ArchConfig, key) -> Dict:
    d = cfg.d_model
    nh = cfg.n_heads
    hd = d // nh
    pdt = common.dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    s = 0.02
    return {
        "ln": common.init_norm(cfg, d),
        "W": jax.random.normal(ks[0], (d, nh, 4, hd), jnp.float32) * s,
        "R": jax.random.normal(ks[1], (nh, hd, 4, hd), jnp.float32) * s,
        "b": jnp.zeros((nh, 4, hd)).at[:, 1].set(3.0),   # forget bias
        "out": jax.random.normal(ks[2], (d, d), pdt)
        * s / max(1, cfg.n_layers) ** 0.5,
    }


def _slstm_cell(p, x_t, state):
    """x_t [B, d]; state (c, n, h) each [B, nh, hd]."""
    c, n, h = state
    g = jnp.einsum("bd,dhgk->bhgk", x_t, p["W"])
    g = g + jnp.einsum("bhk,hkgj->bhgj", h, p["R"]) + p["b"]
    i = jax.nn.sigmoid(g[:, :, 0])
    f = jax.nn.sigmoid(g[:, :, 1])
    z = jnp.tanh(g[:, :, 2])
    o = jax.nn.sigmoid(g[:, :, 3])
    c = f * c + i * z
    n = f * n + i
    h = o * c / jnp.maximum(n, 1.0)
    return (c, n, h)


def slstm_fwd(cfg: ArchConfig, p: Dict, x: jax.Array) -> jax.Array:
    B, S, D = x.shape
    nh = cfg.n_heads
    hd = D // nh
    xin = common.apply_norm(cfg, p["ln"], x).astype(jnp.float32)

    # hoist the input projection out of the recurrent scan: one MXU matmul
    # instead of 4096 tiny ones re-reading W every step
    # (EXPERIMENTS.md §Perf iteration 3a)
    gx = jnp.einsum("bsd,dhgk->bshgk", xin, p["W"]) + p["b"]

    def cell(state, gx_t):
        c, n, h = state
        g = gx_t + jnp.einsum("bhk,hkgj->bhgj", h, p["R"])
        i = jax.nn.sigmoid(g[:, :, 0])
        f = jax.nn.sigmoid(g[:, :, 1])
        z = jnp.tanh(g[:, :, 2])
        o = jax.nn.sigmoid(g[:, :, 3])
        c = f * c + i * z
        n = f * n + i
        h = o * c / jnp.maximum(n, 1.0)
        return (c, n, h), h

    # blocked time loop: T_BLOCK unrolled steps per scan iteration — the
    # recurrence is exact but loop-boundary traffic amortizes 8x
    # (EXPERIMENTS.md §Perf iteration 5)
    T_BLOCK = 8 if S % 8 == 0 else 1
    gx_t = gx.transpose(1, 0, 2, 3, 4)             # [S, B, nh, 4, hd]
    gx_b = gx_t.reshape(S // T_BLOCK, T_BLOCK, B, nh, 4, hd)

    def block(state, gx_blk):
        outs = []
        for t in range(T_BLOCK):
            state, h = cell(state, gx_blk[t])
            outs.append(h)
        return state, jnp.stack(outs)

    init = tuple(jnp.zeros((B, nh, hd), jnp.float32) for _ in range(3))
    _, hs = lax.scan(block, init, gx_b)
    hs = hs.reshape(S, B, nh, hd)
    y = hs.transpose(1, 0, 2, 3).reshape(B, S, D)
    cdt = common.dtype_of(cfg.compute_dtype)
    return x + (y.astype(cdt) @ p["out"].astype(cdt)).astype(x.dtype)


def init_slstm_state(cfg: ArchConfig, B: int):
    nh = cfg.n_heads
    hd = cfg.d_model // nh
    return tuple(jnp.zeros((B, nh, hd), jnp.float32) for _ in range(3))


def slstm_decode(cfg: ArchConfig, p: Dict, x: jax.Array, state):
    xin = common.apply_norm(cfg, p["ln"], x).astype(jnp.float32)[:, 0]
    state = _slstm_cell(p, xin, state)
    B = x.shape[0]
    y = state[2].reshape(B, -1)
    cdt = common.dtype_of(cfg.compute_dtype)
    out = x + (y.astype(cdt) @ p["out"].astype(cdt))[:, None, :].astype(x.dtype)
    return out, state


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------

def _groups(cfg: ArchConfig) -> Tuple[int, int]:
    m = cfg.xlstm.m_per_group
    gsize = m + 1
    assert cfg.n_layers % gsize == 0, (cfg.n_layers, gsize)
    return cfg.n_layers // gsize, m


def init(cfg: ArchConfig, key) -> Dict:
    G, m = _groups(cfg)
    kE, kM, kS = jax.random.split(key, 3)
    mk = jax.random.split(kM, G * m)
    sk = jax.random.split(kS, G)
    return {
        "tok": common.init_embed(cfg, kE),
        "mlstm": jax.vmap(lambda k: init_mlstm(cfg, k))(mk),
        "slstm": jax.vmap(lambda k: init_slstm(cfg, k))(sk),
        "ln_f": common.init_norm(cfg, cfg.d_model),
    }


def forward_train(cfg: ArchConfig, params: Dict, tokens, **_):
    G, m = _groups(cfg)
    x = common.embed_tokens(cfg, params["tok"], tokens)
    x = shard_act(x, "residual")

    ml = jax.tree.map(
        lambda a: a.reshape((G, m) + a.shape[1:]), params["mlstm"]
    )

    # remat at PER-LAYER granularity: checkpointing the whole 8-layer group
    # makes the backward stack every layer's intermediates ([7, B, S, ...])
    # before consuming them (EXPERIMENTS.md §Perf iteration 4)
    def one_mlstm(x, lp):
        y = mlstm_fwd(cfg, lp, x)
        return shard_act(y, "residual"), ()

    def one_slstm(x, sp):
        y = slstm_fwd(cfg, sp, x)
        return shard_act(y, "residual"), ()

    if cfg.remat:
        one_mlstm = jax.checkpoint(one_mlstm, policy=None)
        one_slstm = jax.checkpoint(one_slstm, policy=None)

    def group(x, xs):
        mlp_g, sl_g = xs
        x, _ = lax.scan(one_mlstm, x, mlp_g)
        x, _ = one_slstm(x, sl_g)
        return x, ()

    x, _ = lax.scan(group, x, (ml, params["slstm"]))
    x = common.apply_norm(cfg, params["ln_f"], x)
    logits = common.unembed(cfg, params["tok"], x)
    return logits, {"aux_loss": jnp.zeros((), jnp.float32)}


def init_cache(cfg: ArchConfig, B: int, Smax: int = 0, dtype=jnp.bfloat16):
    G, m = _groups(cfg)
    mst = init_mlstm_state(cfg, B)
    return {
        "mlstm": jax.tree.map(
            lambda x: jnp.broadcast_to(x, (G * m,) + x.shape), mst
        ),
        "slstm": jax.tree.map(
            lambda x: jnp.broadcast_to(x, (G,) + x.shape),
            init_slstm_state(cfg, B),
        ),
    }


def decode_step(cfg: ArchConfig, params: Dict, tokens, cache, lengths):
    G, m = _groups(cfg)
    x = common.embed_tokens(cfg, params["tok"], tokens[:, None])
    new_m, new_s = [], []
    for g in range(G):
        for j in range(m):
            li = g * m + j
            lp = jax.tree.map(lambda a, li=li: a[li], params["mlstm"])
            st = jax.tree.map(lambda a, li=li: a[li], cache["mlstm"])
            x, st = mlstm_decode(cfg, lp, x, st)
            new_m.append(st)
        sp = jax.tree.map(lambda a, g=g: a[g], params["slstm"])
        st = jax.tree.map(lambda a, g=g: a[g], cache["slstm"])
        x, st = slstm_decode(cfg, sp, x, st)
        new_s.append(st)
    x = common.apply_norm(cfg, params["ln_f"], x)
    logits = common.unembed(cfg, params["tok"], x)[:, 0]
    cache = {
        "mlstm": jax.tree.map(lambda *xs: jnp.stack(xs), *new_m),
        "slstm": jax.tree.map(lambda *xs: jnp.stack(xs), *new_s),
    }
    return logits, cache
