"""Mamba2 (SSD) block — chunked-parallel training form + recurrent decode.

State-space recurrence per head (scalar-decay SSD, as in Mamba2):

    h_t = a_t * h_{t-1} + u_t ⊗ B_t          h: [P, N]
    y_t = (h_t @ C_t) + D * x_t

with a_t = exp(A·dt_t) in (0,1], u_t = dt_t * x_t.  Training uses the
chunked decomposition (chunk Q): intra-chunk is an attention-like
[Q, Q] masked matmul (MXU work), inter-chunk carries the [P, N] state
through a short lax.scan over S/Q chunks — O(S·Q) FLOPs, O(S/Q)
sequential depth, and bounded activation memory (the roofline-relevant
property for long_500k).  Decode is the plain one-step recurrence.

``ssd_chunked`` is shared with the mLSTM block (repro.models.xlstm), whose
matrix-memory update has the same algebra (DESIGN.md Sec 6).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ArchConfig
from repro.models import common


def ssd_chunked(u, a, Bm, Cm, chunk: int):
    """u [B,S,H,P]; a [B,S,H] decay; Bm/Cm [B,S,H,N] -> y [B,S,H,P], h_last.

    Exact evaluation of the recurrence above (initial state 0).
    """
    B, S, H, P = u.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, f"seq {S} % chunk {Q} != 0"
    nc = S // Q

    def r(x):
        return x.reshape(B, nc, Q, *x.shape[2:])

    u_, a_, B_, C_ = r(u), r(a), r(Bm), r(Cm)
    la = jnp.log(jnp.maximum(a_, 1e-20)).astype(jnp.float32)   # [B,nc,Q,H]
    cum = jnp.cumsum(la, axis=2)                                # inclusive

    # mixed precision: the big [B,S,...] operands stream in bf16; only the
    # small per-chunk decay/state tensors stay f32 (accumulation via
    # preferred_element_type) — EXPERIMENTS.md §Perf iteration 4.
    bf = jnp.bfloat16
    # intra-chunk: score[i,j] = (C_i . B_j) * exp(cum_i - cum_j) , j <= i
    scores = jnp.einsum("bcqhn,bckhn->bchqk", C_.astype(bf), B_.astype(bf),
                        preferred_element_type=jnp.float32)
    cumh = cum.transpose(0, 1, 3, 2)                            # [B,nc,H,Q]
    decay = jnp.exp(cumh[..., :, None] - cumh[..., None, :])
    # decay[b,c,h,q,k] = exp(cum_q - cum_k)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    w = jnp.where(mask[None, None, None], scores * decay, 0.0)
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", w.astype(bf), u_.astype(bf),
                         preferred_element_type=jnp.float32)

    # inter-chunk: scan over chunk boundary states
    # state contribution of chunk c: sum_j exp(cum_last - cum_j) u_j ⊗ B_j
    tail = jnp.exp(cum[:, :, -1:, :] - cum)                     # [B,nc,Q,H]
    chunk_state = jnp.einsum(
        "bcqh,bcqhp,bcqhn->bchpn", tail.astype(bf), u_.astype(bf),
        B_.astype(bf), preferred_element_type=jnp.float32,
    )                                                            # [B,nc,H,P,N]
    chunk_decay = jnp.exp(cum[:, :, -1, :])                     # [B,nc,H]

    def step(h, xs):
        st, dc = xs                                              # [B,H,P,N], [B,H]
        h_out = h                                                # state entering chunk
        h = h * dc[:, :, None, None] + st
        return h, h_out

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    h_last, h_in = lax.scan(
        step, h0,
        (chunk_state.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    h_in = h_in.transpose(1, 0, 2, 3, 4)                         # [B,nc,H,P,N]
    y_inter = jnp.einsum(
        "bcqhn,bchpn->bcqhp", C_.astype(jnp.bfloat16),
        h_in.astype(jnp.bfloat16), preferred_element_type=jnp.float32,
    ) * jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(B, S, H, P)
    return y, h_last


def ssd_recurrent_step(h, u_t, a_t, B_t, C_t):
    """One decode step. h [B,H,P,N]; u_t [B,H,P]; a_t [B,H]; B_t/C_t [B,H,N]."""
    h = h * a_t[:, :, None, None] + jnp.einsum("bhp,bhn->bhpn", u_t, B_t)
    y = jnp.einsum("bhpn,bhn->bhp", h, C_t)
    return h, y


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------

def init_block(cfg: ArchConfig, key) -> Dict:
    s = cfg.ssm
    d = cfg.d_model
    d_inner = s.expand * d
    H = d_inner // s.head_dim
    convdim = d_inner + 2 * s.d_state
    pdt = common.dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    return {
        "ln": common.init_norm(cfg, d),
        "in_proj": jax.random.normal(
            ks[0], (d, 2 * d_inner + 2 * s.d_state + H), pdt) * 0.02,
        "conv_w": jax.random.normal(ks[1], (s.d_conv, convdim), pdt) * 0.2,
        "conv_b": jnp.zeros((convdim,), pdt),
        "A_log": jnp.zeros((H,), jnp.float32),
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "out_norm": common.init_norm(cfg, d_inner),
        "out_proj": jax.random.normal(ks[2], (d_inner, d), pdt)
        * 0.02 / max(1, cfg.n_layers) ** 0.5,
    }


def _split_proj(cfg: ArchConfig, proj):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    z = proj[..., :d_inner]
    xbc = proj[..., d_inner : 2 * d_inner + 2 * s.d_state]
    dt = proj[..., 2 * d_inner + 2 * s.d_state :]
    return z, xbc, dt, d_inner, H


def block_fwd(cfg: ArchConfig, p: Dict, x: jax.Array) -> jax.Array:
    """x [B, S, D] -> [B, S, D] (training/prefill, chunked)."""
    s = cfg.ssm
    cdt = common.dtype_of(cfg.compute_dtype)
    Bsz, S, D = x.shape
    h = common.apply_norm(cfg, p["ln"], x).astype(cdt)
    proj = h @ p["in_proj"].astype(cdt)
    z, xbc, dt, d_inner, H = _split_proj(cfg, proj)

    # causal depthwise conv over seq
    K = s.d_conv
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    conv = sum(
        pad[:, i : i + S, :] * p["conv_w"].astype(cdt)[i][None, None, :]
        for i in range(K)
    ) + p["conv_b"].astype(cdt)
    conv = jax.nn.silu(conv)
    xs = conv[..., :d_inner].reshape(Bsz, S, H, s.head_dim)
    Bm = conv[..., d_inner : d_inner + s.d_state]
    Cm = conv[..., d_inner + s.d_state :]
    Bm = jnp.broadcast_to(Bm[:, :, None, :], (Bsz, S, H, s.d_state))
    Cm = jnp.broadcast_to(Cm[:, :, None, :], (Bsz, S, H, s.d_state))

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])     # [B,S,H]
    A = -jnp.exp(p["A_log"])                                         # [H]
    a = jnp.exp(A[None, None, :] * dt)
    u = xs.astype(jnp.float32) * dt[..., None]

    y, _ = ssd_chunked(u, a, Bm, Cm, s.chunk)
    y = y + xs.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(Bsz, S, d_inner).astype(cdt)
    y = y * jax.nn.silu(z)
    y = common.apply_norm(cfg, p["out_norm"], y)
    out = y @ p["out_proj"].astype(cdt)
    return x + out.astype(x.dtype)


def init_state(cfg: ArchConfig, B: int):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    convdim = d_inner + 2 * s.d_state
    return {
        "h": jnp.zeros((B, H, s.head_dim, s.d_state), jnp.float32),
        "conv": jnp.zeros((B, s.d_conv - 1, convdim), jnp.float32),
    }


def block_decode(cfg: ArchConfig, p: Dict, x: jax.Array, state: Dict):
    """x [B, 1, D] one token; returns (y [B,1,D], state')."""
    s = cfg.ssm
    cdt = common.dtype_of(cfg.compute_dtype)
    Bsz = x.shape[0]
    h = common.apply_norm(cfg, p["ln"], x).astype(cdt)
    proj = (h @ p["in_proj"].astype(cdt))[:, 0]
    z, xbc, dt, d_inner, H = _split_proj(cfg, proj)

    hist = jnp.concatenate(
        [state["conv"], xbc[:, None, :].astype(jnp.float32)], axis=1
    )                                                            # [B, K, convdim]
    conv = jnp.einsum("bkc,kc->bc", hist, p["conv_w"].astype(jnp.float32))
    conv = jax.nn.silu(conv + p["conv_b"].astype(jnp.float32))
    new_conv = hist[:, 1:, :]

    xs = conv[:, :d_inner].reshape(Bsz, H, s.head_dim)
    Bm = jnp.broadcast_to(
        conv[:, None, d_inner : d_inner + s.d_state], (Bsz, H, s.d_state))
    Cm = jnp.broadcast_to(
        conv[:, None, d_inner + s.d_state :], (Bsz, H, s.d_state))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,H]
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(A[None, :] * dt)
    u = xs.astype(jnp.float32) * dt[..., None]
    hstate, y = ssd_recurrent_step(state["h"], u, a, Bm, Cm)
    y = y + xs.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(Bsz, d_inner).astype(cdt)
    y = y * jax.nn.silu(z)
    y = common.apply_norm(cfg, p["out_norm"], y)
    out = (y @ p["out_proj"].astype(cdt))[:, None, :]
    return x + out.astype(x.dtype), {"h": hstate, "conv": new_conv}
