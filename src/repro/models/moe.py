"""Mixture-of-Experts FFN with sort-based (TPU-friendly) dispatch.

Top-k routing with static per-expert capacity.  Dispatch is the sort-based
formulation: flatten (token, choice) assignments, sort by expert id, take
the first C per expert (capacity drop), gather token activations into a
dense [E, C, D] block, run all experts as one batched einsum on the MXU,
and scatter-add weighted outputs back.  Compared to the one-hot GShard
dispatch this avoids the [T, E, C] tensor entirely — O(T·k) sort + gathers.

Experts shard over the ``model`` axis (EP); the [E, C, D] blocks carry an
explicit sharding constraint so the all-to-all happens on the compact
dispatched form, not on the full activations.

Aux losses: switch-style load balance + router z-loss.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ArchConfig, MoEConfig
from repro.distributed.ctx import shard_act
from repro.models import common


def init_moe(cfg: ArchConfig, key) -> Dict:
    m = cfg.moe
    d = cfg.d_model
    pdt = common.dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    s = 0.02
    p = {
        "router": jax.random.normal(ks[0], (d, m.n_experts), jnp.float32) * s,
        "w1": jax.random.normal(ks[1], (m.n_experts, d, m.d_expert), pdt) * s,
        "w3": jax.random.normal(ks[2], (m.n_experts, d, m.d_expert), pdt) * s,
        "w2": jax.random.normal(ks[3], (m.n_experts, m.d_expert, d), pdt)
        * s / max(1, cfg.n_layers) ** 0.5,
    }
    if m.n_shared:
        p["shared"] = common.init_mlp(
            cfg, ks[4], d_ff=m.d_expert * m.n_shared
        )
    return p


def moe_fwd(cfg: ArchConfig, p: Dict, x: jax.Array) -> Tuple[jax.Array, Dict]:
    """x [B, S, D] -> (out [B, S, D], aux-loss dict)."""
    m: MoEConfig = cfg.moe
    cdt = common.dtype_of(cfg.compute_dtype)
    B, S, D = x.shape
    T = B * S
    E, K = m.n_experts, m.top_k
    # tokens leave sequence-parallel layout before dispatch: one explicit
    # all-gather here, instead of XLA resolving the dispatch gather against
    # an SP-sharded table with full [E,C,D] f32 all-reduces
    # (EXPERIMENTS.md §Perf iteration 7)
    xt = shard_act(x.reshape(T, D), "moe_tokens")

    logits = (xt.astype(jnp.float32) @ p["router"])            # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, choice = jax.lax.top_k(probs, K)                      # [T, K]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # ---- sort-based dispatch -------------------------------------------
    C = max(1, int(T * K / E * m.capacity_factor))
    flat_e = choice.reshape(-1).astype(jnp.int32)               # [T*K]
    flat_t = (
        jnp.arange(T * K, dtype=jnp.int32) // K                 # token of each slot
    )
    flat_g = gate.reshape(-1)
    # sort ints only (expert id, slot id); gather float gates through the
    # permutation so gradients flow via gather, not sort-vjp
    perm0 = jnp.arange(T * K, dtype=jnp.int32)
    se, sperm = jax.lax.sort((flat_e, perm0), num_keys=2)
    st = flat_t[sperm]
    sg = flat_g[sperm]
    # position within expert segment
    idx = jnp.arange(T * K, dtype=jnp.int32)
    seg_start = jnp.where(
        jnp.concatenate([jnp.ones((1,), bool), se[1:] != se[:-1]]), idx, -1
    )
    seg_start = jax.lax.associative_scan(jnp.maximum, seg_start)
    pos_in_e = idx - seg_start
    keep = pos_in_e < C
    slot = jnp.where(keep, se * C + pos_in_e, E * C)            # E*C = dropped

    # gather tokens into [E, C, D]
    tok_at_slot = jnp.full((E * C + 1,), T, jnp.int32).at[slot].set(
        st, mode="drop"
    )[: E * C]
    gate_at_slot = jnp.zeros((E * C + 1,), jnp.float32).at[slot].set(
        sg, mode="drop"
    )[: E * C]
    xt_pad = jnp.concatenate([xt, jnp.zeros((1, D), xt.dtype)], 0)
    xe = xt_pad[tok_at_slot].reshape(E, C, D).astype(cdt)
    xe = shard_act(xe, "moe_experts")        # EP: experts over model axis

    # ---- expert compute (single batched einsum; EP over model axis) ----
    # gather-on-use (ZeRO): pull the FSDP-sharded expert weights together
    # BEFORE the einsums — otherwise XLA all-reduces the (much larger)
    # [E, C, D] activations over the FSDP axis (§Perf iteration 7)
    w1 = shard_act(p["w1"].astype(cdt), "moe_weight")
    w3 = shard_act(p["w3"].astype(cdt), "moe_weight")
    w2 = shard_act(p["w2"].astype(cdt), "moe_weight")
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    h = act(jnp.einsum("ecd,edf->ecf", xe, w1))
    h = h * jnp.einsum("ecd,edf->ecf", xe, w3)
    ye = jnp.einsum("ecf,efd->ecd", h, w2)                      # [E, C, D]

    # ---- combine: weighted scatter-add back to tokens -------------------
    # bf16 combine: the scatter-add result is psum'd over the model axis
    # (EP combine); bf16 halves those wire bytes (§Perf iteration 6)
    yflat = (ye.reshape(E * C, D).astype(jnp.float32)
             * gate_at_slot[:, None]).astype(cdt)
    out = jnp.zeros((T + 1, D), cdt).at[tok_at_slot].add(yflat)[:T]
    out = out.astype(x.dtype).reshape(B, S, D)

    if m.n_shared:
        out = out + common.mlp_fwd(cfg, p["shared"], x)

    # ---- aux losses ------------------------------------------------------
    me = probs.mean(axis=0)                                     # [E]
    ce = jnp.zeros((E,), jnp.float32).at[flat_e].add(1.0) / (T * K)
    balance = E * jnp.sum(me * ce) * m.balance_coef
    z = jnp.mean(jax.nn.logsumexp(logits, -1) ** 2) * m.router_z_coef
    dropped = 1.0 - keep.mean()
    aux = {
        "moe_balance": balance,
        "moe_z": z,
        "moe_dropped": dropped,
    }
    return out, aux
