"""Shared model blocks: norms, RoPE, attention, MLPs, embeddings, losses.

Pure functions over param dicts (pytrees).  Initializers take an explicit
PRNG key; every block also works under ``jax.eval_shape`` for the dry-run.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ArchConfig
from repro.distributed.ctx import shard_act

NEG_INF = -1e30


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ArchConfig, d: int):
    if cfg.norm == "layernorm_np":      # olmo: non-parametric LayerNorm
        return {}
    return {"scale": jnp.ones((d,), dtype_of(cfg.param_dtype))}


def apply_norm(cfg: ArchConfig, p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm_np":
        mu = xf.mean(-1, keepdims=True)
        var = xf.var(-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps)
    else:
        out = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
        out = out * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


def rms_headnorm(scale, x, eps: float = 1e-6):
    """qwen3-style per-head qk-norm: x [..., hd], scale [hd]."""
    xf = x.astype(jnp.float32)
    out = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x [..., S, hd] (hd even), positions broadcastable to [..., S]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (XLA path with dynamic window; Pallas path via repro.kernels)
# ---------------------------------------------------------------------------

def init_attention(cfg: ArchConfig, key) -> Dict:
    d, H, KVH, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    pdt = dtype_of(cfg.param_dtype)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 0.02
    p = {
        "wq": jax.random.normal(k1, (d, H, hd), pdt) * s,
        "wk": jax.random.normal(k2, (d, KVH, hd), pdt) * s,
        "wv": jax.random.normal(k3, (d, KVH, hd), pdt) * s,
        "wo": jax.random.normal(k4, (H, hd, d), pdt) * s / max(1, cfg.n_layers) ** 0.5,
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), pdt)
        p["k_norm"] = jnp.ones((hd,), pdt)
    return p


def _mask_logits(s, qpos, kpos, window, causal: bool):
    """s [..., Sq, Sk]; window is a traced scalar (0 = full)."""
    mask = jnp.ones(s.shape[-2:], bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    win_ok = (window <= 0) | ((qpos[:, None] - kpos[None, :]) < window)
    mask &= win_ok
    return jnp.where(mask, s, NEG_INF)


# chunk queries when the full [*, S, S] score tensor would exceed VMEM-scale
# temp budgets (exact: each q row sees the full key set) — the XLA analogue
# of the Pallas flash kernel, required for the prefill_32k cells to fit HBM
_QCHUNK_THRESHOLD = 8192
_QCHUNK = 1024


def _sdpa(q, k, v, window, causal: bool, hd: int):
    """q [B,H,S,hd], k/v [B,KVH,S,hd] (GQA) -> [B,H,S,hd]."""
    B, H, S, _ = q.shape
    KVH = k.shape[1]
    group = H // KVH
    qg = q.reshape(B, KVH, group, S, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    def block(q_blk, q0):
        s = jnp.einsum("bkgqd,bksd->bkgqs", q_blk, kf) / (hd ** 0.5)
        qpos = q0 + jnp.arange(q_blk.shape[3])
        kpos = jnp.arange(S)
        mask = jnp.ones((q_blk.shape[3], S), bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        mask &= (window <= 0) | ((qpos[:, None] - kpos[None, :]) < window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        pr = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bkgqs,bksd->bkgqd", pr, vf)

    if S <= _QCHUNK_THRESHOLD or S % _QCHUNK != 0:
        o = block(qg, 0)
    else:
        nc = S // _QCHUNK
        qc = qg.reshape(B, KVH, group, nc, _QCHUNK, hd).transpose(
            3, 0, 1, 2, 4, 5)

        def body(c, q_blk):
            return c + 1, block(q_blk, c * _QCHUNK)

        _, oc = jax.lax.scan(body, jnp.int32(0), qc)
        o = oc.transpose(1, 2, 3, 0, 4, 5).reshape(B, KVH, group, S, hd)
    return o.reshape(B, H, S, hd)


def attention_fwd(
    cfg: ArchConfig,
    p: Dict,
    x: jax.Array,                 # [B, S, D]
    positions: jax.Array,         # [S] or [B, S]
    *,
    window,                       # scalar (traced ok); 0 = full
    causal: bool = True,
    return_kv: bool = False,
):
    cdt = dtype_of(cfg.compute_dtype)
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    xc = x.astype(cdt)
    q = jnp.einsum("bsd,dhk->bhsk", xc, p["wq"].astype(cdt))
    k = jnp.einsum("bsd,dhk->bhsk", xc, p["wk"].astype(cdt))
    v = jnp.einsum("bsd,dhk->bhsk", xc, p["wv"].astype(cdt))
    if cfg.qk_norm:
        q = rms_headnorm(p["q_norm"], q)
        k = rms_headnorm(p["k_norm"], k)
    if positions.ndim == 1:
        pos_b = positions[None, None, :]
    else:
        pos_b = positions[:, None, :]
    q = rope(q, pos_b, cfg.rope_theta)
    k = rope(k, pos_b, cfg.rope_theta)

    S = x.shape[1]
    o = _sdpa(q, k, v, window, causal, hd).astype(cdt)
    out = jnp.einsum("bhqk,hkd->bqd", o, p["wo"].astype(cdt))
    out = out.astype(x.dtype)
    if return_kv:
        # [B, KVH, S, hd] (post-RoPE, pre-GQA-repeat) — KV-cache layout
        return out, k, v
    return out


def attention_decode(
    cfg: ArchConfig,
    p: Dict,
    x: jax.Array,                 # [B, 1, D] current token hidden
    cache_k: jax.Array,           # [B, KVH, Smax, hd]
    cache_v: jax.Array,
    lengths: jax.Array,           # [B] valid cache length (before this token)
    *,
    window,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    cdt = dtype_of(cfg.compute_dtype)
    B = x.shape[0]
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    xc = x.astype(cdt)
    q = jnp.einsum("bsd,dhk->bhsk", xc, p["wq"].astype(cdt))[:, :, 0]   # [B,H,hd]
    k = jnp.einsum("bsd,dhk->bhsk", xc, p["wk"].astype(cdt))[:, :, 0]
    v = jnp.einsum("bsd,dhk->bhsk", xc, p["wv"].astype(cdt))[:, :, 0]
    if cfg.qk_norm:
        q = rms_headnorm(p["q_norm"], q)
        k = rms_headnorm(p["k_norm"], k)
    pos = lengths.astype(jnp.float32)
    q = rope(q, pos[:, None], cfg.rope_theta)
    k = rope(k, pos[:, None], cfg.rope_theta)

    # append (k, v) at position lengths[b]
    bidx = jnp.arange(B)
    cache_k = cache_k.at[bidx, :, lengths, :].set(k.astype(cache_k.dtype))
    cache_v = cache_v.at[bidx, :, lengths, :].set(v.astype(cache_v.dtype))
    cache_k = shard_act(cache_k, "kv4")
    cache_v = shard_act(cache_v, "kv4")

    Smax = cache_k.shape[2]
    group = H // KVH
    # grouped-query attention against the resident cache: no KV repeat, no
    # f32 cache copy — bf16 reads with f32 MXU accumulation
    # (EXPERIMENTS.md §Perf iteration 2)
    qg = q.reshape(B, KVH, group, hd).astype(cache_k.dtype)
    s = jnp.einsum("bkgd,bksd->bkgs", qg, cache_k,
                   preferred_element_type=jnp.float32) / (hd ** 0.5)
    kpos = jnp.arange(Smax)[None, None, None, :]
    ok = kpos <= lengths[:, None, None, None]
    ok &= (window <= 0) | (kpos > lengths[:, None, None, None] - window)
    s = jnp.where(ok, s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bksd->bkgd", pr.astype(cache_v.dtype), cache_v,
                   preferred_element_type=jnp.float32)
    o = o.reshape(B, H, hd).astype(cdt)
    out = jnp.einsum("bhk,hkd->bd", o, p["wo"].astype(cdt))[:, None, :]
    return out.astype(x.dtype), cache_k, cache_v


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU) and embeddings
# ---------------------------------------------------------------------------

def init_mlp(cfg: ArchConfig, key, d_ff: Optional[int] = None) -> Dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    pdt = dtype_of(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    s = 0.02
    return {
        "w1": jax.random.normal(k1, (d, f), pdt) * s,
        "w3": jax.random.normal(k2, (d, f), pdt) * s,
        "w2": jax.random.normal(k3, (f, d), pdt) * s / max(1, cfg.n_layers) ** 0.5,
    }


def mlp_fwd(cfg: ArchConfig, p: Dict, x: jax.Array) -> jax.Array:
    cdt = dtype_of(cfg.compute_dtype)
    xc = x.astype(cdt)
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    h = act(xc @ p["w1"].astype(cdt)) * (xc @ p["w3"].astype(cdt))
    return (h @ p["w2"].astype(cdt)).astype(x.dtype)


def init_embed(cfg: ArchConfig, key) -> Dict:
    pdt = dtype_of(cfg.param_dtype)
    p = {"embed": jax.random.normal(key, (cfg.vocab, cfg.d_model), pdt) * 0.02}
    if not cfg.tie_embeddings:
        p["unembed"] = (
            jax.random.normal(
                jax.random.fold_in(key, 1), (cfg.d_model, cfg.vocab), pdt
            ) * 0.02
        )
    return p


def embed_tokens(cfg: ArchConfig, p: Dict, tokens: jax.Array) -> jax.Array:
    x = p["embed"][tokens].astype(dtype_of(cfg.compute_dtype))
    if cfg.family == "dense" and cfg.name.startswith("gemma"):
        x = x * (cfg.d_model ** 0.5)  # gemma embedding scaling
    return x


def unembed(cfg: ArchConfig, p: Dict, x: jax.Array) -> jax.Array:
    cdt = dtype_of(cfg.compute_dtype)
    if cfg.tie_embeddings:
        w = p["embed"].astype(cdt).T
    else:
        w = p["unembed"].astype(cdt)
    logits = x.astype(cdt) @ w
    return shard_act(logits, "logits")


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def cross_entropy(logits: jax.Array, labels: jax.Array, mask: jax.Array,
                  z_coef: float = 1e-4):
    """Token CE with z-loss; logits [B,S,V] (any dtype), labels [B,S]."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    z = z_coef * (lse ** 2)
    m = mask.astype(jnp.float32)
    denom = jnp.maximum(m.sum(), 1.0)
    loss = ((nll + z) * m).sum() / denom
    acc = (((lf.argmax(-1) == labels) & mask).sum() / denom)
    return loss, {"nll": (nll * m).sum() / denom, "acc": acc}
