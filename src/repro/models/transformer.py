"""Transformer stack (dense / MoE / VLM / audio-encoder families).

Layers are *stacked*: every per-layer param pytree carries a leading [L]
axis and the stack runs under ``lax.scan`` (small HLO, fast multi-pod
compiles, remat-friendly).  Per-layer attention windows (gemma3's 5:1
local:global pattern) ride along as scan xs.

API (all pure):
  init(cfg, key)                 -> params
  forward_train(cfg, params, tokens, patches=None, embeds=None)
                                 -> (logits [B,S,V], aux)
  init_cache(cfg, B, Smax)       -> cache pytree
  decode_step(cfg, params, tokens [B], cache, lengths [B])
                                 -> (logits [B,V], cache')
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ArchConfig
from repro.distributed.ctx import shard_act
from repro.models import common
from repro.models.moe import init_moe, moe_fwd


def layer_windows(cfg: ArchConfig) -> np.ndarray:
    """Per-layer sliding window sizes (0 = full attention)."""
    if cfg.window <= 0:
        return np.zeros((cfg.n_layers,), np.int32)
    w = np.full((cfg.n_layers,), cfg.window, np.int32)
    if cfg.global_every > 0:
        w[cfg.global_every - 1 :: cfg.global_every] = 0  # every k-th is global
    return w


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(cfg: ArchConfig, key) -> Dict:
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": common.init_norm(cfg, cfg.d_model),
        "attn": common.init_attention(cfg, k1),
        "ln2": common.init_norm(cfg, cfg.d_model),
    }
    if cfg.moe is not None:
        p["moe"] = init_moe(cfg, k2)
    else:
        p["mlp"] = common.init_mlp(cfg, k2)
    return p


def init(cfg: ArchConfig, key) -> Dict:
    kE, kL, kP = jax.random.split(key, 3)
    layer_keys = jax.random.split(kL, cfg.n_layers)
    layers = jax.vmap(lambda k: _init_layer(cfg, k))(layer_keys)
    params = {
        "tok": common.init_embed(cfg, kE),
        "layers": layers,
        "ln_f": common.init_norm(cfg, cfg.d_model),
    }
    if cfg.vlm is not None:
        pdt = common.dtype_of(cfg.param_dtype)
        ka, kb = jax.random.split(kP)
        params["projector"] = {
            "w1": jax.random.normal(
                ka, (cfg.vlm.patch_dim, cfg.d_model), pdt) * 0.02,
            "w2": jax.random.normal(
                kb, (cfg.d_model, cfg.d_model), pdt) * 0.02,
        }
    if cfg.encoder_only:
        # audio frontend stub: frame features arrive at d_model directly;
        # a learned input norm stands in for the conv feature projector.
        params["in_norm"] = common.init_norm(cfg, cfg.d_model)
    return params


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _layer_fwd(cfg: ArchConfig, lp: Dict, x, positions, window, causal: bool):
    h = common.apply_norm(cfg, lp["ln1"], x)
    x = x + common.attention_fwd(
        cfg, lp["attn"], h, positions, window=window, causal=causal
    )
    x = shard_act(x, "residual")
    h = common.apply_norm(cfg, lp["ln2"], x)
    if cfg.moe is not None:
        y, aux = moe_fwd(cfg, lp["moe"], h)
    else:
        y, aux = common.mlp_fwd(cfg, lp["mlp"], h), {}
    x = x + y
    x = shard_act(x, "residual")
    return x, aux


def _stack(cfg: ArchConfig, params: Dict, x, positions, causal: bool):
    windows = jnp.asarray(layer_windows(cfg))

    def body(carry, xs):
        lp, window = xs
        x = carry
        x, aux = _layer_fwd(cfg, lp, x, positions, window, causal)
        aux_sum = sum(aux.values()) if aux else jnp.zeros((), jnp.float32)
        moe_aux = aux if aux else {
            "moe_balance": jnp.zeros((), jnp.float32),
            "moe_z": jnp.zeros((), jnp.float32),
            "moe_dropped": jnp.zeros((), jnp.float32),
        }
        return x, (aux_sum, moe_aux)

    fn = jax.checkpoint(body, policy=None) if cfg.remat else body
    x, (aux_sums, moe_aux) = lax.scan(fn, x, (params["layers"], windows))
    aux = {k: v.mean() for k, v in moe_aux.items()} if cfg.moe else {}
    aux["aux_loss"] = aux_sums.sum()
    return x, aux


def forward_train(
    cfg: ArchConfig,
    params: Dict,
    tokens: Optional[jax.Array] = None,     # [B, S_text]
    patches: Optional[jax.Array] = None,    # [B, NP, patch_dim] (vlm)
    embeds: Optional[jax.Array] = None,     # [B, S, d_model]    (audio)
) -> Tuple[jax.Array, Dict]:
    causal = not cfg.encoder_only
    if cfg.encoder_only:
        x = common.apply_norm(cfg, params["in_norm"], embeds.astype(
            common.dtype_of(cfg.compute_dtype)))
    else:
        x = common.embed_tokens(cfg, params["tok"], tokens)
        if cfg.vlm is not None:
            cdt = common.dtype_of(cfg.compute_dtype)
            pe = patches.astype(cdt) @ params["projector"]["w1"].astype(cdt)
            pe = jax.nn.gelu(pe) @ params["projector"]["w2"].astype(cdt)
            x = jnp.concatenate([pe, x], axis=1)
    x = shard_act(x, "residual")
    S = x.shape[1]
    positions = jnp.arange(S)
    x, aux = _stack(cfg, params, x, positions, causal)
    x = common.apply_norm(cfg, params["ln_f"], x)
    logits = common.unembed(cfg, params["tok"], x)
    return logits, aux


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def prefill(
    cfg: ArchConfig, params: Dict, tokens: jax.Array, Smax: int,
    cache_dtype=jnp.bfloat16,
) -> Tuple[jax.Array, Dict]:
    """Forward over a prompt batch, capturing the KV cache.

    Returns (logits [B, S, V], cache with k/v valid on [:S]).  Padded prompt
    tails are handled by the caller via per-sequence lengths (causality keeps
    pads from contaminating earlier positions).
    """
    x = common.embed_tokens(cfg, params["tok"], tokens)
    x = shard_act(x, "residual")
    B, S = tokens.shape
    positions = jnp.arange(S)
    windows = jnp.asarray(layer_windows(cfg))

    def body(x, xs):
        lp, window = xs
        h = common.apply_norm(cfg, lp["ln1"], x)
        a, k, v = common.attention_fwd(
            cfg, lp["attn"], h, positions, window=window, causal=True,
            return_kv=True,
        )
        x = x + a
        h = common.apply_norm(cfg, lp["ln2"], x)
        if cfg.moe is not None:
            y, _ = moe_fwd(cfg, lp["moe"], h)
        else:
            y = common.mlp_fwd(cfg, lp["mlp"], h)
        return x + y, (k.astype(cache_dtype), v.astype(cache_dtype))

    x, (ks, vs) = lax.scan(body, x, (params["layers"], windows))
    x = common.apply_norm(cfg, params["ln_f"], x)
    logits = common.unembed(cfg, params["tok"], x)
    pad = Smax - S
    cache = {
        "k": jnp.pad(ks, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0))),
        "v": jnp.pad(vs, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0))),
    }
    return logits, cache


def init_cache(cfg: ArchConfig, B: int, Smax: int, dtype=jnp.bfloat16):
    KVH, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    shape = (cfg.n_layers, B, KVH, Smax, hd)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


def decode_step(
    cfg: ArchConfig,
    params: Dict,
    tokens: jax.Array,       # [B]
    cache: Dict,
    lengths: jax.Array,      # [B]
) -> Tuple[jax.Array, Dict]:
    x = common.embed_tokens(cfg, params["tok"], tokens[:, None])  # [B,1,D]
    windows = jnp.asarray(layer_windows(cfg))

    def body(x, xs):
        lp, ck, cv, window = xs
        h = common.apply_norm(cfg, lp["ln1"], x)
        a, ck, cv = common.attention_decode(
            cfg, lp["attn"], h, ck, cv, lengths, window=window
        )
        x = x + a
        h = common.apply_norm(cfg, lp["ln2"], x)
        if cfg.moe is not None:
            y, _ = moe_fwd(cfg, lp["moe"], h)
        else:
            y = common.mlp_fwd(cfg, lp["mlp"], h)
        return x + y, (ck, cv)

    x, (ck, cv) = lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"], windows)
    )
    x = common.apply_norm(cfg, params["ln_f"], x)
    logits = common.unembed(cfg, params["tok"], x)[:, 0]
    return logits, {"k": ck, "v": cv}
