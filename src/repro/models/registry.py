"""Uniform model API over the four family implementations."""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.config import ArchConfig, ShapeConfig
from repro.models import transformer, xlstm, zamba


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    init: Callable
    forward_train: Callable          # (cfg, params, **batch) -> (logits, aux)
    init_cache: Optional[Callable]   # (cfg, B, Smax, dtype) -> cache
    decode_step: Optional[Callable]  # (cfg, params, tokens, cache, lengths)


def get_model(cfg: ArchConfig) -> ModelAPI:
    if cfg.hybrid_attn_every > 0:
        return ModelAPI(zamba.init, zamba.forward_train,
                        zamba.init_cache, zamba.decode_step)
    if cfg.xlstm is not None:
        return ModelAPI(xlstm.init, xlstm.forward_train,
                        xlstm.init_cache, xlstm.decode_step)
    dec = None if cfg.encoder_only else transformer.decode_step
    cache = None if cfg.encoder_only else transformer.init_cache
    return ModelAPI(transformer.init, transformer.forward_train, cache, dec)


def param_shapes(cfg: ArchConfig) -> Dict:
    """Abstract param pytree (no allocation) for dry-runs."""
    api = get_model(cfg)
    return jax.eval_shape(lambda k: api.init(cfg, k), jax.random.key(0))


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    Modality frontends are stubs: audio provides frame embeddings at
    d_model, VLM provides InternViT patch features (DESIGN.md Sec 6).
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if shape.kind in ("train", "prefill"):
        if cfg.encoder_only:
            return {
                "embeds": sds((B, S, cfg.d_model), jnp.bfloat16),
                "labels": sds((B, S), i32),
                "mask": sds((B, S), jnp.bool_),
            }
        if cfg.vlm is not None:
            st = S - cfg.vlm.n_patches
            return {
                "tokens": sds((B, st), i32),
                "patches": sds((B, cfg.vlm.n_patches, cfg.vlm.patch_dim),
                               jnp.bfloat16),
                "labels": sds((B, st), i32),
                "mask": sds((B, st), jnp.bool_),
            }
        return {
            "tokens": sds((B, S), i32),
            "labels": sds((B, S), i32),
            "mask": sds((B, S), jnp.bool_),
        }
    # decode: one new token against a cache of length S
    api = get_model(cfg)
    cache = jax.eval_shape(lambda: api.init_cache(cfg, B, S))
    return {
        "tokens": sds((B,), i32),
        "lengths": sds((B,), i32),
        "cache": cache,
    }
