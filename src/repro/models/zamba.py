"""Zamba2-style hybrid: Mamba2 backbone + a *shared* attention block.

Every ``hybrid_attn_every``-th layer applies one globally shared
attention+MLP block (same params at every occurrence) — the Zamba2 trick
of amortizing attention params across a cheap SSM backbone.  (The released
model alternates two shared blocks; we use one — DESIGN.md Sec 6.)

Backbone layers scan with stacked params; the shared block is closed over
and applied under ``lax.cond`` on the layer index.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ArchConfig
from repro.distributed.ctx import shard_act
from repro.models import common, mamba2


def _init_shared(cfg: ArchConfig, key) -> Dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": common.init_norm(cfg, cfg.d_model),
        "attn": common.init_attention(cfg, k1),
        "ln2": common.init_norm(cfg, cfg.d_model),
        "mlp": common.init_mlp(cfg, k2),
    }


def init(cfg: ArchConfig, key) -> Dict:
    kE, kL, kS = jax.random.split(key, 3)
    layer_keys = jax.random.split(kL, cfg.n_layers)
    return {
        "tok": common.init_embed(cfg, kE),
        "mamba": jax.vmap(lambda k: mamba2.init_block(cfg, k))(layer_keys),
        "shared": _init_shared(cfg, kS),
        "ln_f": common.init_norm(cfg, cfg.d_model),
    }


def _shared_fwd(cfg: ArchConfig, sp: Dict, x, positions):
    h = common.apply_norm(cfg, sp["ln1"], x)
    x = x + common.attention_fwd(
        cfg, sp["attn"], h, positions, window=jnp.int32(0), causal=True
    )
    h = common.apply_norm(cfg, sp["ln2"], x)
    return x + common.mlp_fwd(cfg, sp["mlp"], h)


def forward_train(cfg: ArchConfig, params: Dict, tokens, **_) -> Tuple:
    x = common.embed_tokens(cfg, params["tok"], tokens)
    x = shard_act(x, "residual")
    S = x.shape[1]
    positions = jnp.arange(S)
    k = cfg.hybrid_attn_every

    def body(x, xs):
        lp, idx = xs
        x = mamba2.block_fwd(cfg, lp, x)
        x = lax.cond(
            (idx + 1) % k == 0,
            lambda h: _shared_fwd(cfg, params["shared"], h, positions),
            lambda h: h,
            x,
        )
        x = shard_act(x, "residual")
        return x, ()

    fn = jax.checkpoint(body, policy=None) if cfg.remat else body
    x, _ = lax.scan(fn, x, (params["mamba"], jnp.arange(cfg.n_layers)))
    x = common.apply_norm(cfg, params["ln_f"], x)
    logits = common.unembed(cfg, params["tok"], x)
    return logits, {"aux_loss": jnp.zeros((), jnp.float32)}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, B: int, Smax: int, dtype=jnp.bfloat16):
    n_occ = cfg.n_layers // cfg.hybrid_attn_every
    st = mamba2.init_state(cfg, B)
    KVH, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "mamba": jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape), st
        ),
        "attn_k": jnp.zeros((n_occ, B, KVH, Smax, hd), dtype),
        "attn_v": jnp.zeros((n_occ, B, KVH, Smax, hd), dtype),
    }


def decode_step(cfg: ArchConfig, params: Dict, tokens, cache, lengths):
    x = common.embed_tokens(cfg, params["tok"], tokens[:, None])
    k = cfg.hybrid_attn_every
    sp = params["shared"]
    new_mamba = []
    ak, av = cache["attn_k"], cache["attn_v"]
    for i in range(cfg.n_layers):
        lp = jax.tree.map(lambda a, i=i: a[i], params["mamba"])
        st = jax.tree.map(lambda a, i=i: a[i], cache["mamba"])
        x, st = mamba2.block_decode(cfg, lp, x, st)
        new_mamba.append(st)
        if (i + 1) % k == 0:
            occ = (i + 1) // k - 1
            h = common.apply_norm(cfg, sp["ln1"], x)
            a, nk, nv = common.attention_decode(
                cfg, sp["attn"], h, ak[occ], av[occ], lengths,
                window=jnp.int32(0),
            )
            x = x + a
            ak = ak.at[occ].set(nk)
            av = av.at[occ].set(nv)
            h = common.apply_norm(cfg, sp["ln2"], x)
            x = x + common.mlp_fwd(cfg, sp["mlp"], h)
    x = common.apply_norm(cfg, params["ln_f"], x)
    logits = common.unembed(cfg, params["tok"], x)[:, 0]
    new_cache = {
        "mamba": jax.tree.map(lambda *xs: jnp.stack(xs), *new_mamba),
        "attn_k": ak,
        "attn_v": av,
    }
    return logits, new_cache
