"""The typed plan IR of the public API: `OpBatch`, `Result`, `RangePage`.

An `OpBatch` is the paper's announce array as ONE typed value instead of
parallel ``(codes, keys, values, k1, k2, snap_ts)`` arrays: op i is
``codes[i]`` applied to ``keys[i]`` (k1 for RANGEQUERY) with ``values[i]``
(the inserted value, or k2 for RANGEQUERY).  Linearization is announce
order — op i runs at timestamp ``base_ts + i`` — exactly the contract of
``RefStore.apply_batch`` and ``repro.core.batch``.

All three classes are registered pytree dataclasses: they flatten to their
array leaves, cross ``jax.jit`` boundaries, and are safe to donate
(``donate_argnums``) — the fields are plain ``int32``/``bool`` arrays with
no static metadata, so same-shape batches never retrace a jitted consumer.

Builders produce host (numpy) arrays — the IR is assembled on the host and
crosses to the device once, inside the executor's single fused pass.
``concat`` / ``pad_to`` stay jnp-based when handed traced values, so plans
can also be composed inside jit.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, List, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.ref import (
    KEY_MAX, NOT_FOUND, TOMBSTONE,
    OP_DELETE, OP_INSERT, OP_NOP, OP_RANGE, OP_SEARCH,
)


def _is_traced(*arrays) -> bool:
    return any(isinstance(a, jax.Array) for a in arrays)


def _np1d(x) -> np.ndarray:
    return np.atleast_1d(np.asarray(x, np.int32))


def pow2_width(n: int) -> int:
    """The power-of-two shape bucket for a width-``n`` plan (>= 1)."""
    return 1 << max(0, int(n) - 1).bit_length() if n else 1


def check_keys(keys, what: str = "key") -> None:
    """Front-door key-domain guard: reject the two sentinels.

    ``KEY_MAX`` is the padding sentinel and ``KEY_MAX - 1`` the kernels'
    internal pad value (valid keys are ``< KEY_MAX - 1``, ref.py).  The
    store accepts either silently and then misbehaves — an INSERT at a
    sentinel key is published but ``lookup`` never finds it — so the
    builders and read verbs raise here, on the host, before any device
    work.
    """
    k = np.asarray(keys)
    if k.size and bool(np.any(k >= KEY_MAX - 1)):
        bad = int(k[np.asarray(k >= KEY_MAX - 1)].flat[0])
        raise ValueError(
            f"{what} {bad} is in the sentinel range [KEY_MAX-1, KEY_MAX] "
            f"(valid keys are < {KEY_MAX - 1}); the store would accept it "
            "and then silently never find it")


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OpBatch:
    """A typed announce array: ``codes[P]``, ``keys[P]``, ``values[P]``.

    ``codes[i]`` in {OP_INSERT, OP_DELETE, OP_SEARCH, OP_RANGE, OP_NOP}.
    For OP_RANGE, ``keys[i]`` is k1 and ``values[i]`` is k2 (inclusive).
    Padded slots are ``(OP_NOP, KEY_MAX, 0)``.
    """

    codes: jax.Array   # int32 [P]
    keys: jax.Array    # int32 [P]
    values: jax.Array  # int32 [P]

    # ---------------------------------------------------------- constructors
    @classmethod
    def inserts(cls, keys, values) -> "OpBatch":
        """INSERT(keys[i], values[i]) for every i (values broadcastable)."""
        k = _np1d(keys)
        check_keys(k, "INSERT key")
        v = np.broadcast_to(_np1d(values), k.shape).astype(np.int32)
        return cls(np.full(k.shape, OP_INSERT, np.int32), k, v.copy())

    @classmethod
    def deletes(cls, keys) -> "OpBatch":
        k = _np1d(keys)
        check_keys(k, "DELETE key")
        return cls(np.full(k.shape, OP_DELETE, np.int32), k,
                   np.zeros(k.shape, np.int32))

    @classmethod
    def searches(cls, keys) -> "OpBatch":
        k = _np1d(keys)
        check_keys(k, "SEARCH key")
        return cls(np.full(k.shape, OP_SEARCH, np.int32), k,
                   np.zeros(k.shape, np.int32))

    @classmethod
    def ranges(cls, k1, k2) -> "OpBatch":
        """RANGEQUERY([k1[i], k2[i]]) — op i snapshots at its own timestamp."""
        a = _np1d(k1)
        check_keys(a, "RANGE k1")
        b = np.broadcast_to(_np1d(k2), a.shape).astype(np.int32)
        check_keys(b, "RANGE k2")
        return cls(np.full(a.shape, OP_RANGE, np.int32), a, b.copy())

    @classmethod
    def updates(cls, keys, values) -> "OpBatch":
        """Legacy (keys, values) update encoding: TOMBSTONE value -> DELETE,
        KEY_MAX key -> NOP, otherwise INSERT (the pre-PR-1 announce shape).
        KEY_MAX stays the documented NOP-padding encoding here; the
        undocumented sentinel KEY_MAX - 1 is rejected like everywhere else.
        """
        k = _np1d(keys)
        if k.size and bool(np.any(k == KEY_MAX - 1)):
            raise ValueError(
                f"update key {KEY_MAX - 1} is the internal pad sentinel "
                f"(valid keys are < {KEY_MAX - 1}; KEY_MAX pads to NOP)")
        v = np.broadcast_to(_np1d(values), k.shape).astype(np.int32)
        codes = np.where(
            k >= KEY_MAX, OP_NOP,
            np.where(v == TOMBSTONE, OP_DELETE, OP_INSERT),
        ).astype(np.int32)
        return cls(codes, k, v.copy())

    @classmethod
    def from_ops(cls, ops: Sequence[Tuple[int, int, int]]) -> "OpBatch":
        """From a list of (op_code, key, value) tuples (oracle encoding)."""
        arr = np.asarray(list(ops), np.int32).reshape(-1, 3)
        check_keys(arr[:, 1][arr[:, 0] != OP_NOP], "key")
        check_keys(arr[:, 2][arr[:, 0] == OP_RANGE], "RANGE k2")
        return cls(arr[:, 0].copy(), arr[:, 1].copy(), arr[:, 2].copy())

    @classmethod
    def empty(cls) -> "OpBatch":
        z = np.zeros((0,), np.int32)
        return cls(z, z.copy(), z.copy())

    # ------------------------------------------------------------- combinators
    @classmethod
    def concat(cls, *batches: "OpBatch") -> "OpBatch":
        """Concatenate plans in announce order (jit-safe on traced inputs)."""
        if not batches:
            return cls.empty()
        leaves = [a for b in batches for a in (b.codes, b.keys, b.values)]
        xp = jnp if _is_traced(*leaves) else np
        return cls(
            xp.concatenate([b.codes for b in batches]),
            xp.concatenate([b.keys for b in batches]),
            xp.concatenate([b.values for b in batches]),
        )

    def pad_to(self, width: int) -> "OpBatch":
        """Pad with NOPs to ``width`` (fixed-shape plans: no retracing)."""
        n = len(self)
        if width < n:
            raise ValueError(f"pad_to({width}) below batch width {n}")
        if width == n:
            return self
        r = width - n
        xp = jnp if _is_traced(self.codes, self.keys, self.values) else np
        return OpBatch(
            xp.concatenate([self.codes, xp.full((r,), OP_NOP, xp.int32)]),
            xp.concatenate([self.keys, xp.full((r,), KEY_MAX, xp.int32)]),
            xp.concatenate([self.values, xp.zeros((r,), xp.int32)]),
        )

    def pad_to_pow2(self) -> "OpBatch":
        """NOP-pad to the next power-of-two width (``pow2_width``): ragged
        caller widths collapse to O(log max_width) jit shape buckets."""
        return self.pad_to(pow2_width(len(self)))

    # ---------------------------------------------------------------- queries
    def __len__(self) -> int:
        return int(self.codes.shape[0])

    @property
    def range_positions(self) -> np.ndarray:
        """Announce positions of the RANGE ops (host-side)."""
        return np.nonzero(np.asarray(self.codes) == OP_RANGE)[0]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RangePage:
    """One bounded range-scan pass over Q intervals (rows key-sorted).

    ``truncated[q]`` means interval q was not fully covered by this pass;
    re-enter from ``resume_k1[q]`` (the exact no-skip/no-duplicate resume
    frontier of DESIGN.md Sec 8).
    """

    keys: jax.Array       # int32 [Q, R], KEY_MAX padded
    values: jax.Array     # int32 [Q, R], NOT_FOUND padded
    count: jax.Array      # int32 [Q]
    truncated: jax.Array  # bool  [Q]
    resume_k1: jax.Array  # int32 [Q]

    def items(self, q: int = 0) -> List[Tuple[int, int]]:
        """Query q's (key, value) page as a host list."""
        c = int(np.asarray(self.count)[q])
        k = np.asarray(self.keys)[q, :c]
        v = np.asarray(self.values)[q, :c]
        return list(zip(k.tolist(), v.tolist()))


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Result:
    """Per-op outcome of ``Uruv.apply`` in announce order.

    * ``values[i]``     — INSERT/DELETE: previous value (NOT_FOUND if new);
                          SEARCH: value at the op's snapshot; RANGE: number
                          of live keys in [k1, k2] at the op's snapshot;
                          NOP/padded: NOT_FOUND.
    * ``found[i]``      — ``values[i] != NOT_FOUND`` (a RANGE op is always
                          "found": its count is never NOT_FOUND).
    * ``timestamps[i]`` — the op's linearization timestamp (base_ts + i).
    * ``range_index``   — announce positions of the RANGE ops, in order.
    * ``range_pages``   — one ``[n_q, 2]`` (key, value) array per RANGE op
                          (complete — the executor paginates in-pass and
                          re-enters until every interval is covered).
    * ``range_resume``  — per RANGE op, the frontier after the answered
                          pages: k2 for a complete answer (always, under
                          ``Uruv.apply``), the exact resume key otherwise.
    """

    values: jax.Array                       # int32 [P]
    found: jax.Array                        # bool  [P]
    timestamps: jax.Array                   # int32 [P]
    range_index: jax.Array                  # int32 [Qr]
    range_pages: Tuple[jax.Array, ...]      # Qr x int32 [n_q, 2]
    range_resume: jax.Array                 # int32 [Qr]

    def __len__(self) -> int:
        return int(self.values.shape[0])

    def page(self, announce_pos: int) -> List[Tuple[int, int]]:
        """The (key, value) page of the RANGE op at ``announce_pos``."""
        idx = np.asarray(self.range_index).tolist()
        arr = np.asarray(self.range_pages[idx.index(int(announce_pos))])
        return [(int(k), int(v)) for k, v in arr]

    def pages(self) -> List[List[Tuple[int, int]]]:
        """All RANGE pages, in announce order of the RANGE ops."""
        return [
            [(int(k), int(v)) for k, v in np.asarray(p)]
            for p in self.range_pages
        ]

    @property
    def value(self) -> int:
        """Scalar convenience for single-op batches."""
        if len(self) != 1:
            raise ValueError("Result.value requires a single-op batch")
        return int(np.asarray(self.values)[0])


def make_result(
    values: np.ndarray,
    codes: np.ndarray,
    base_ts: int,
    range_items: Iterable[Tuple[int, List[Tuple[int, int]], int]] = (),
) -> Result:
    """Assemble a Result from executor outputs.

    ``range_items`` yields (announce_pos, page, resume_k1) per RANGE op.
    """
    values = np.asarray(values, np.int64)
    codes = np.asarray(codes, np.int32)
    n = len(values)
    idx, pages, resumes = [], [], []
    for pos, page, resume in range_items:
        idx.append(pos)
        pages.append(np.asarray(page, np.int32).reshape(-1, 2))
        resumes.append(resume)
    return Result(
        values=values,
        found=(values != NOT_FOUND) & (codes != OP_NOP),
        timestamps=(base_ts + np.arange(n)).astype(np.int32),
        range_index=np.asarray(idx, np.int32),
        range_pages=tuple(pages),
        range_resume=np.asarray(resumes, np.int32),
    )
