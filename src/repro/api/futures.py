"""Deferred-result surface of the public API: `PendingPlan`.

The serving front end (`repro.serve.coalescer`) overlaps host work with
device execution: it dispatches plan N, then builds and routes plan N+1
while the device is still executing N, and only *then* pays the first host
sync for N.  The client primitive behind that is the ``apply_nowait`` /
``confirm`` pair:

  * ``Uruv.apply_nowait(batch)`` dispatches ONE fast-path device pass for a
    CRUD-only plan and returns immediately with a :class:`PendingPlan` —
    the speculative store, the device-resident result values, and the
    device-resident accept flag.  No ``jax.block_until_ready`` /
    ``np.asarray`` happens at dispatch; the client adopts the speculative
    store so further plans can be dispatched behind it.
  * ``Uruv.confirm(pending)`` is the deferred sync: it blocks on the accept
    flag, and either materialises the per-op :class:`Result` (success) or
    rolls the client back to the pre-plan store and returns ``None`` —
    the caller then replays the plan through the synchronous ``apply``
    path, which owns the slow-path/lifecycle machinery.

Speculation is safe because ``store.bulk_apply`` rejects atomically: a
rejected pass returns the input pools untouched (plus the ``oflow`` bits)
and does not advance the clock, so the pre-plan state is always
recoverable — from the host reference (``store_before``) normally, or from
the passed-through reject store when the pass donated its input buffers.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.api.opbatch import OpBatch


@dataclasses.dataclass
class PendingPlan:
    """One dispatched-but-unconfirmed CRUD plan (see module docstring).

    ``batch`` is the plan exactly as dispatched (padding included) with
    host (numpy) leaves, so a rejected plan can be replayed bit-exactly.
    ``n_user`` is the caller's pre-padding width — ``confirm`` slices the
    materialised result back to it.  ``store_before`` is ``None`` when the
    pass donated the store buffers (exclusive-owner mode); rollback then
    recovers the pre-plan state from the atomically-rejected ``store_after``.
    """

    batch: OpBatch
    n_user: int
    store_before: Optional[Any]     # pre-dispatch store pytree (not donated)
    store_after: Any                # speculative store pytree
    values: jax.Array               # int32 [P] device result (speculative)
    ok: jax.Array                   # bool [] device accept flag

    def rollback_store(self):
        """The pre-plan store: the held host reference, or the rejected
        pass's passthrough pools with the overflow bits cleared."""
        if self.store_before is not None:
            return self.store_before
        return dataclasses.replace(
            self.store_after, oflow=jnp.zeros_like(self.store_after.oflow))
