"""repro.api — the single public entry point to the Uruv ADT.

Everything outside ``repro.core`` (serving, data, benchmarks, examples)
talks to the store through this package only:

  * :class:`OpBatch`  — the typed announce-array plan IR (builders:
    ``inserts/deletes/searches/ranges/updates``, ``concat``, ``pad_to``).
  * :class:`Result`   — per-op values + found mask + timestamps + complete
    range pages and resume frontiers.
  * :class:`Uruv`     — the client: ``apply(batch)``, convenience verbs,
    ``snapshot()`` context manager, ``range``/``range_all`` pagination,
    lifecycle verbs ``maintain()``/``grow()``, ``compact()``.
  * :class:`LocalExecutor` / :class:`ShardedExecutor` — pluggable
    topology backends behind one executor contract (DESIGN.md Sec 9).
  * :class:`LifecyclePolicy` — the self-sizing store lifecycle
    (DESIGN.md Sec 10): auto-grow on capacity pressure + interleaved
    incremental maintenance are ON by default; ``CapacityError`` (with
    occupancy/frozen-fraction diagnostics) is the opt-in fixed-footprint
    contract.

Old entry points (``core.batch.apply_updates``, ``core.batch.
range_query_all``, ``core.store.bulk_update``) are deprecated delegates
of this API.
"""

from repro.core.backend import get_backend, set_backend
from repro.core.batch import CapacityError
from repro.core.lifecycle import (
    LifecyclePolicy, PoolWatermarks, pool_watermarks, version_tail_start,
)
from repro.core.ref import (
    KEY_DOMAIN_HI, KEY_MAX, NOT_FOUND, TOMBSTONE,
    OP_DELETE, OP_INSERT, OP_NOP, OP_RANGE, OP_SEARCH,
)
from repro.core.sharded import ShardedConfig
from repro.core.store import UruvConfig

from repro.api.client import Uruv
from repro.api.executors import LocalExecutor, RangeOptions, ShardedExecutor
from repro.api.futures import PendingPlan
from repro.api.opbatch import (
    OpBatch, RangePage, Result, make_result, pow2_width,
)

__all__ = [
    "CapacityError",
    "KEY_DOMAIN_HI",
    "KEY_MAX",
    "LifecyclePolicy",
    "PoolWatermarks",
    "pool_watermarks",
    "version_tail_start",
    "LocalExecutor",
    "NOT_FOUND",
    "OP_DELETE",
    "OP_INSERT",
    "OP_NOP",
    "OP_RANGE",
    "OP_SEARCH",
    "OpBatch",
    "PendingPlan",
    "RangeOptions",
    "RangePage",
    "Result",
    "ShardedConfig",
    "ShardedExecutor",
    "TOMBSTONE",
    "Uruv",
    "UruvConfig",
    "get_backend",
    "make_result",
    "pow2_width",
    "set_backend",
]
