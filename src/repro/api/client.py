"""`Uruv` — the one front door to the paper's ADT.

One client serves every topology: construct with a `UruvConfig` for a
single device, with ``Uruv.sharded(cfg, mesh)`` for a key-partitioned
mesh — every verb below then runs through the pluggable executor without
the caller ever branching on topology.

    from repro.api import OpBatch, Uruv, UruvConfig

    db = Uruv(UruvConfig(leaf_cap=32))
    db.insert([1, 2, 3], [10, 20, 30])
    res = db.apply(OpBatch.concat(
        OpBatch.searches([2]), OpBatch.deletes([1]), OpBatch.ranges(0, 99),
    ))                       # one linearized announce array, one device pass
    with db.snapshot() as ts:            # registered + auto-released
        page = db.range(0, 99, ts)       # consistent under later updates

The client is the ONLY stateful object in the stack: it holds the current
store pytree (every prior value remains a valid frozen snapshot — the
paper's freeze-for-free) and mutates nothing else.  All heavy lifting is
the executor's; the client adds the announce-order timestamp accounting
(`Result.timestamps`) and the snapshot-tracker hygiene.
"""

from __future__ import annotations

import contextlib
from typing import Iterator, List, Optional, Tuple

import numpy as np

from repro.analysis.marks import device_pass
from repro.core import store as _store
from repro.core.ref import KEY_MAX, OP_RANGE

from repro.api.executors import (
    LifecyclePolicy, LocalExecutor, RangeOptions, ShardedExecutor,
)
from repro.api.futures import PendingPlan
from repro.api.opbatch import (
    OpBatch, RangePage, Result, make_result, pow2_width,
)


class Uruv:
    """Stateful client over an immutable store + a pluggable executor.

    The store is SELF-SIZING by default (DESIGN.md Sec 10): capacity
    pressure grows the flagged pool in place (device-resident power-of-two
    doubling, bit-exact) and incremental ``maintain`` passes reclaim
    retired split-leavings and merge underfull leaves when the frozen
    fraction crosses the policy trigger — a client created with a small
    ``UruvConfig`` serves an arbitrarily large working set without ever
    raising ``CapacityError``.  Pass ``policy=LifecyclePolicy(
    auto_grow=False, auto_maintain=False)`` for the fixed-footprint
    (seed) behaviour.
    """

    def __init__(self, config: Optional[_store.UruvConfig] = None, *,
                 executor=None, store=None, backend: Optional[str] = None,
                 policy: Optional[LifecyclePolicy] = None,
                 durable_dir: Optional[str] = None, group_commit: int = 1):
        if executor is None:
            executor = LocalExecutor(config, backend=backend, policy=policy)
        self.executor = executor
        self._store = store if store is not None else executor.create()
        self.recovery = None       # set by Uruv.recover()
        if durable_dir is not None:
            from repro.durability.recovery import Durability

            dur = Durability(durable_dir, group_commit=group_commit)
            if dur.has_history:
                raise ValueError(
                    f"{durable_dir} already holds durable history; a fresh "
                    "client would fork it — use Uruv.recover()")
            dur.write_config(self.config)
            self._attach_durability(dur)

    # ----------------------------------------------------------- constructors
    @classmethod
    def sharded(cls, config, mesh, *, route_factor: int = 2,
                routed: bool = True, store=None,
                policy: Optional[LifecyclePolicy] = None) -> "Uruv":
        """A client over a key-range-partitioned store on ``mesh`` (the
        ``config`` is a ``repro.core.sharded.ShardedConfig``)."""
        return cls(executor=ShardedExecutor(
            config, mesh, route_factor=route_factor, routed=routed,
            policy=policy,
        ), store=store)

    @classmethod
    def from_store(cls, store, *, backend: Optional[str] = None,
                   policy: Optional[LifecyclePolicy] = None) -> "Uruv":
        """Adopt an existing single-device store pytree (zero copies —
        stores are immutable, so the donor keeps its snapshot)."""
        return cls(executor=LocalExecutor(store.cfg, backend=backend,
                                          policy=policy),
                   store=store)

    @classmethod
    def recover(cls, durable_dir: str, *, backend: Optional[str] = None,
                policy: Optional[LifecyclePolicy] = None,
                group_commit: int = 1) -> "Uruv":
        """Rebuild a ``durable_dir=...`` client after a crash: restore the
        newest complete checkpoint (full or delta chain), replay the WAL
        tail at its recorded timestamps — bit-identical values AND version
        timestamps — and keep logging into the same directory.  The
        :class:`repro.durability.recovery.RecoveryInfo` lands on
        ``db.recovery`` (DESIGN.md Sec 14)."""
        from repro.durability.recovery import recover as _recover

        return _recover(durable_dir, backend=backend, policy=policy,
                        group_commit=group_commit)

    # ------------------------------------------------------------ durability
    def _attach_durability(self, durability) -> None:
        self.executor.durability = durability

    @property
    def durability(self):
        """The attached durability sidecar (None for a volatile client)."""
        return getattr(self.executor, "durability", None)

    def sync_durable(self) -> None:
        """Close the group-commit window: fsync every logged-but-pending
        plan.  A no-op for a volatile client."""
        dur = self.durability
        if dur is not None:
            dur.sync()

    def checkpoint(self, *, delta: bool = True) -> int:
        """Checkpoint the current store into the durable directory (delta
        against the previous checkpoint when one exists — first save is
        always full) and prune WAL segments the checkpoint covers.
        Returns the checkpoint step (the store clock)."""
        dur = self.durability
        if dur is None:
            raise ValueError(
                "checkpoint() requires a durable client "
                "(Uruv(durable_dir=...) or Uruv.recover())")
        return dur.checkpoint(
            self._store, delta=delta,
            compactions=self.executor.stats.get("compactions", 0))

    # ----------------------------------------------------------------- state
    @property
    def store(self):
        """The current store pytree (an immutable snapshot)."""
        return self._store

    @property
    def config(self):
        return self.executor.config

    @property
    def stats(self):
        """Executor counters: ``device_passes`` / ``slow_path_rounds`` /
        ``compactions`` plus the lifecycle trio ``grows`` /
        ``maintain_passes`` / ``leaves_reclaimed``, merged with the
        device-resident index counters ``index_delta_passes`` (structural
        batches that ran the bounded separator-delta pass) and
        ``index_propagations`` (node updates that propagated above the
        bottom level — the observable O(touched·depth) bound of
        DESIGN.md Sec 11; sharded stores sum their shards)."""
        s = dict(self.executor.stats)
        idx = getattr(self._store, "index", None)
        if idx is not None:
            s["index_delta_passes"] = int(
                np.asarray(idx.stat_delta_passes).sum())
            s["index_propagations"] = int(
                np.asarray(idx.stat_propagations).sum())
        return s

    @property
    def capacity(self):
        """The LIVE capacities (``store.cfg``) — these move as the store
        grows; the construction-time config keeps the initial sizes."""
        return self._store.cfg

    @property
    def ts(self) -> int:
        """The global clock (the paper's FAA counter)."""
        return self.executor.ts(self._store)

    @property
    def active_snapshots(self) -> int:
        """Registered-and-unreleased snapshots in the version tracker."""
        act = np.asarray(self._store.trk_active)
        if act.ndim == 2:        # sharded: the tracker ring is replicated
            act = act[0]
        return int(act.sum())

    # ----------------------------------------------------------------- write
    def apply(self, batch: OpBatch, *, light_path: bool = True,
              pad_to_pow2: bool = False,
              range_opts: RangeOptions = RangeOptions()) -> Result:
        """Linearize one announce array: op i at timestamp ``ts + i``.

        One device pass on the fast path (CRUD-only batches); RANGE ops
        segment the array (each range snapshots at its own announce
        timestamp and is answered COMPLETELY).  Capacity rejections retry
        via the bounded slow path; ``CapacityError`` if the store cannot
        fit the working set even after compaction.

        ``pad_to_pow2`` NOP-pads the plan to the next power-of-two width
        before dispatch, bounding jit retraces to O(log max_width) shape
        buckets for callers with ragged batch sizes (serving admission);
        results keep the caller's width, but the clock advances by the
        padded width (NOP slots still occupy announce positions).
        """
        base = self.ts
        n = len(batch)
        if pad_to_pow2 and n:
            batch = batch.pad_to(pow2_width(n))
        self._store, values, range_items = self.executor.apply(
            self._store, batch, light_path=light_path, range_opts=range_opts,
        )
        return make_result(values[:n], np.asarray(batch.codes)[:n], base,
                           range_items)

    # ------------------------------------------------- pipelined (deferred)
    @device_pass(static=("pad_to_pow2", "donate_store"))
    def apply_nowait(self, batch: OpBatch, *, pad_to_pow2: bool = False,
                     donate_store: bool = False) -> PendingPlan:
        """Dispatch a CRUD-only plan WITHOUT waiting for the device.

        Returns a :class:`PendingPlan` immediately — the device pass (and
        its accept/reject decision) is still in flight; the client adopts
        the speculative store so the next plan can be built and dispatched
        behind it (the serving pipeline's two-plans-in-flight overlap,
        DESIGN.md Sec 12).  Settle with :meth:`confirm` IN DISPATCH ORDER
        before using any synchronous verb.  Plans with RANGE ops must take
        :meth:`apply` (their pagination loop is host-driven).

        ``donate_store=True`` additionally donates the store pools into
        the pass — only for an exclusive owner (it invalidates every other
        live reference to this client's store buffers, e.g. a
        ``from_store`` donor), and only with at most one unconfirmed plan
        in flight (a second speculative pass would consume the rejected
        pass's rollback buffers).
        """
        n = len(batch)
        if n == 0:
            raise ValueError("apply_nowait requires a non-empty plan")
        # plan marshalling is host-side BY DESIGN: OpBatch arrays are
        # numpy before dispatch, so these never sync the device
        codes = np.asarray(batch.codes)  # uruvlint: disable=device-pass-purity
        if bool((codes == OP_RANGE).any()):  # uruvlint: disable=device-pass-purity
            raise ValueError(
                "apply_nowait is CRUD-only; RANGE plans take apply()")
        host = OpBatch(codes,  # uruvlint: disable=device-pass-purity
                       np.asarray(batch.keys),  # uruvlint: disable=device-pass-purity
                       np.asarray(batch.values))  # uruvlint: disable=device-pass-purity
        if pad_to_pow2:
            host = host.pad_to(pow2_width(n))
        store_before = self._store
        self._store, values, ok = self.executor.apply_nowait(
            self._store, host, donate_store=donate_store,
        )
        return PendingPlan(
            batch=host, n_user=n,
            store_before=None if donate_store else store_before,
            store_after=self._store, values=values, ok=ok,
        )

    def confirm(self, pending: PendingPlan) -> Optional[Result]:
        """Settle one :meth:`apply_nowait` dispatch (the deferred host
        sync).  On acceptance returns the plan's :class:`Result` (sliced
        back to the caller's pre-padding width).  On rejection rolls the
        client back to the pre-plan store and returns ``None`` — the
        caller replays ``pending.batch`` (and every later unconfirmed
        plan, whose speculative results are invalid) through :meth:`apply`,
        which owns the slow-path and lifecycle machinery and re-derives
        the exact same announce timestamps from the restored clock.
        """
        if not bool(np.asarray(pending.ok)):
            self._store = pending.rollback_store()
            return None
        base = int(np.asarray(pending.store_after.ts)) - len(pending.batch)
        dur = self.durability
        if dur is not None:
            # log-on-confirm (the pipelined half of confirm-after-fsync):
            # an ACCEPTED plan is logged here, before its Result exists; a
            # rejected plan is never logged — its replay logs through
            # apply(), so the WAL carries exactly one record per base_ts
            dur.log_plan(base, np.asarray(pending.batch.codes),
                         np.asarray(pending.batch.keys),
                         np.asarray(pending.batch.values))
        values = np.asarray(pending.values)[:pending.n_user]
        return make_result(values,
                           np.asarray(pending.batch.codes)[:pending.n_user],
                           base, ())

    def lifecycle_tick(self) -> None:
        """Run the policy's proactive grow/maintain triggers now.  The
        pipelined front end calls this between plans (it reads occupancy,
        i.e. syncs the host) instead of on the dispatch path."""
        self._store = self.executor.lifecycle_tick(self._store)

    def insert(self, keys, values) -> Result:
        """Batched INSERT; ``Result.values`` holds the previous values."""
        return self.apply(OpBatch.inserts(keys, values))

    def delete(self, keys) -> Result:
        """Batched DELETE (tombstones; physical reclaim via compact())."""
        return self.apply(OpBatch.deletes(keys))

    def search(self, keys) -> Result:
        """Batched SEARCH as announce ops (advances the clock; op i sees
        every earlier in-batch op).  For read-only probes at an explicit
        snapshot use :meth:`lookup`."""
        return self.apply(OpBatch.searches(keys))

    # ------------------------------------------------------------------ read
    def lookup(self, keys, snap_ts=None, *,
               pad_to_pow2: bool = False) -> np.ndarray:
        """Read-only batched SEARCH at ``snap_ts`` (default: current clock).

        Does not advance the clock or register a snapshot; padded keys
        (KEY_MAX) return NOT_FOUND.  ``pad_to_pow2`` bounds jit retraces
        for ragged probe widths (reads are side-effect free, so padding
        costs nothing but the wider pass).

        KEY_MAX stays the documented mask-out/padding encoding; the
        internal pad sentinel KEY_MAX - 1 is rejected (a key the store
        can publish but never find — the silent-loss guard of the
        ``OpBatch`` builders, DESIGN.md Sec 12).
        """
        if snap_ts is None:
            snap_ts = self.ts
        keys = np.atleast_1d(np.asarray(keys, np.int32))
        if keys.size and bool(np.any(keys == KEY_MAX - 1)):
            raise ValueError(
                f"lookup key {KEY_MAX - 1} is the internal pad sentinel "
                f"(valid keys are < {KEY_MAX - 1}; KEY_MAX masks out)")
        n = len(keys)
        if pad_to_pow2 and n:
            pad = pow2_width(n) - n
            keys = np.concatenate([keys, np.full(pad, KEY_MAX, np.int32)])
            snap = np.asarray(snap_ts, np.int32)
            if snap.ndim:            # per-op snaps pad too (padded keys are
                snap_ts = np.concatenate(   # KEY_MAX -> NOT_FOUND anyway)
                    [snap, np.zeros(pad, np.int32)])
        return np.asarray(self.executor.lookup(
            self._store, keys, snap_ts,
        ))[:n]

    def range(self, k1: int, k2: int, snap_ts: Optional[int] = None, *,
              max_results: int = 1024, scan_leaves: int = 16,
              max_rounds: int = 8) -> List[Tuple[int, int]]:
        """[k1, k2] answered completely at one snapshot -> (key, value) list.

        ``snap_ts=None`` registers a fresh snapshot for the duration of
        the scan (and always releases it — a leaked registration would pin
        ``min_active_ts`` and starve GC).
        """
        return self.range_all([k1], [k2], snap_ts,
                              max_results=max_results,
                              scan_leaves=scan_leaves,
                              max_rounds=max_rounds)[0]

    def range_all(self, k1s, k2s, snap_ts: Optional[int] = None, *,
                  max_results: int = 1024, scan_leaves: int = 16,
                  max_rounds: int = 8) -> List[List[Tuple[int, int]]]:
        """Q intervals answered completely — ONE batched device pass per
        pagination round shared by ALL still-truncated queries (the pooled
        in-pass budget of DESIGN.md Sec 8), at one consistent snapshot."""
        opts = RangeOptions(max_results=max_results,
                            scan_leaves=scan_leaves, max_rounds=max_rounds)
        if snap_ts is None:
            with self.snapshot() as ts:
                return self.executor.range_all(
                    self._store, k1s, k2s, ts, opts)
        return self.executor.range_all(self._store, k1s, k2s, snap_ts, opts)

    def range_page(self, k1s, k2s, snap_ts, *, max_results: int = 1024,
                   scan_leaves: int = 16, max_rounds: int = 8) -> RangePage:
        """ONE bounded device pass over Q intervals (the wait-free unit);
        resume truncated queries from ``page.resume_k1``."""
        return self.executor.range_page(
            self._store, k1s, k2s, snap_ts,
            RangeOptions(max_results=max_results, scan_leaves=scan_leaves,
                         max_rounds=max_rounds),
        )

    def scan_page(self, k1: int, k2: int, snap_ts, *,
                  max_scan_leaves: int = 64,
                  max_results: int = 1024) -> RangePage:
        """The paper's single-interval RANGEQUERY pass: exactly
        ``max_scan_leaves`` chained leaves, one device call (the seed
        contract; kept as the baseline unit for benchmarks)."""
        return self.executor.scan_page(
            self._store, k1, k2, snap_ts,
            max_scan_leaves=max_scan_leaves, max_results=max_results,
        )

    # --------------------------------------------------------- snapshots, GC
    def acquire_snapshot(self) -> int:
        """Register a snapshot in the version tracker and return its ts.
        Pair with :meth:`release_snapshot`; prefer :meth:`snapshot`."""
        self._store, ts = self.executor.snapshot(self._store)
        return ts

    def release_snapshot(self, snap_ts: int) -> None:
        self._store = self.executor.release(self._store, snap_ts)

    @contextlib.contextmanager
    def snapshot(self) -> Iterator[int]:
        """Registered snapshot as a context manager.

            with db.snapshot() as ts:
                view = db.range(0, hi, ts)     # immune to later updates

        Released on exit even on error (GC never starves).
        """
        ts = self.acquire_snapshot()
        try:
            yield ts
        finally:
            self.release_snapshot(ts)

    def compact(self) -> int:
        """Physically reclaim versions no active snapshot can read and
        repack leaves (paper Appendix E); returns the live-key count.
        Stop-the-world — prefer :meth:`maintain` for steady-state leaf
        reclamation; compact remains the version-pool GC."""
        self._store, n_live = self.executor.compact(self._store)
        return n_live

    def reindex(self) -> None:
        """Repack the internal fat-node index at pack_fill occupancy
        (DESIGN.md Sec 11).  Runs automatically when a structural batch
        rejects with ``OFLOW_INDEX`` (node-pool fragmentation after heavy
        delete/merge churn); call it directly to defragment off-peak.
        Every result — including reads at registered snapshots — is
        byte-identical before and after."""
        self._store = self.executor.reindex(self._store)

    # ------------------------------------------------------------- lifecycle
    def maintain(self, budget: Optional[int] = None, *,
                 phase: int = 0) -> Tuple[int, int]:
        """ONE bounded incremental maintenance pass (DESIGN.md Sec 10):
        purge tracker-dead keys, merge underfull neighbours, reclaim up to
        ``budget`` retired leaf slots.  Returns ``(leaves_reclaimed,
        pairs_merged)``; results at every registered snapshot are
        byte-identical before and after.  Runs automatically on the policy
        trigger — call it directly to schedule maintenance explicitly
        (e.g. off-peak)."""
        self._store, reclaimed, merged = self.executor.maintain(
            self._store, budget, phase=phase,
        )
        return reclaimed, merged

    def grow(self, *, leaves: bool = False, versions: bool = False,
             tracker: bool = False) -> None:
        """Double the selected pools now (device-resident, bit-exact).
        Runs automatically on capacity pressure — call it directly to
        pre-size ahead of a known ingest."""
        self._store = self.executor.grow(
            self._store, leaves=leaves, versions=versions, tracker=tracker,
        )

    # ------------------------------------------------------------ inspection
    def live_items(self) -> List[Tuple[int, int]]:
        """All (key, latest live value) pairs — host-side, O(n); tests."""
        store = self._store
        if np.asarray(store.ts).ndim:          # sharded: walk every shard
            import jax

            shards = [
                jax.tree.map(lambda x, s=s: x[s], store)
                for s in range(np.asarray(store.ts).shape[0])
            ]
            out = []
            for sh in shards:
                out.extend(_store.live_items(sh))
            return sorted(out)
        return _store.live_items(store)

    def __len__(self) -> int:
        return len(self.live_items())

    def __repr__(self) -> str:
        return (f"Uruv(executor={type(self.executor).__name__}, "
                f"ts={self.ts}, leaf_cap={self.config.base.leaf_cap if hasattr(self.config, 'base') else self.config.leaf_cap})")
