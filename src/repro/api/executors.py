"""Pluggable execution backends for the `Uruv` client.

An executor owns HOW a plan runs — which device passes, on what topology —
while the client owns the store value and the ADT surface.  The contract
(DESIGN.md Sec 9):

  * ``create()``                        -> a fresh store pytree
  * ``apply(store, batch, ...)``        -> (store, values[P], range_items)
        linearizes the announce array in announce order (op i at
        ``base_ts + i``), answering RANGE ops COMPLETELY (the executor
        paginates internally); never returns a partially-applied store —
        capacity failures raise ``CapacityError`` after bounded retries.
  * ``lookup(store, keys, snap_ts)``    -> values (read-only, no clock)
  * ``range_page(store, k1s, k2s, snap_ts, ...)`` -> RangePage
        ONE bounded device pass over Q intervals (wait-free unit).
  * ``range_all(store, k1s, k2s, snap_ts, ...)``  -> per-query page lists
        complete answers; re-enters only still-truncated queries.
  * ``snapshot / release / compact / ts`` — tracker + clock surface.

``stats`` (shared with the client) counts ``device_passes``,
``slow_path_rounds`` and ``compactions`` — the observable wait-free bound
(benchmarks assert "one device pass per fast-path batch" through it).

`LocalExecutor` runs on one device via ``repro.core.batch``;
`ShardedExecutor` runs the same plans over a mesh axis via the
``repro.core.sharded`` SPMD factories (replicated or routed announce
distribution + all_gather'ed range merge) with bit-identical
linearization, including version timestamps.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.analysis.marks import device_pass
from repro.core import batch as _batch
from repro.core import lifecycle as _lifecycle
from repro.core import sharded as _sharded
from repro.core import store as _store
from repro.api.opbatch import OpBatch, RangePage

CapacityError = _batch.CapacityError
LifecyclePolicy = _lifecycle.LifecyclePolicy


def _new_stats() -> Dict[str, int]:
    return {"device_passes": 0, "slow_path_rounds": 0, "compactions": 0,
            "grows": 0, "maintain_passes": 0, "leaves_reclaimed": 0}


@dataclasses.dataclass(frozen=True)
class RangeOptions:
    """Leaf/result budget of one bounded range pass (DESIGN.md Sec 8)."""

    max_results: int = 1024
    scan_leaves: int = 16
    max_rounds: int = 8


class LocalExecutor:
    """Single-device execution over ``repro.core.store`` / ``core.batch``.

    ``backend`` pins the kernel backend (xla | pallas | pallas_interpret)
    for every pass this executor issues; None follows the process-wide
    ``repro.core.backend`` resolution (URUV_BACKEND / set_backend).

    ``policy`` is the store lifecycle (DESIGN.md Sec 10): with the default
    self-sizing policy the executor grows the rejected pool on capacity
    overflow (power-of-two device-resident doubling, bit-exact) and
    interleaves bounded incremental ``lifecycle.maintain`` passes when the
    frozen/dead fraction of the leaf pool crosses the trigger — no
    steady-state ``CapacityError``.  ``policy=LifecyclePolicy(
    auto_grow=False, auto_maintain=False)`` restores the seed
    fixed-footprint behaviour.  Note the live capacities are carried by
    ``store.cfg`` (the construction-time ``config`` keeps the *initial*
    sizes once growth has occurred).
    """

    def __init__(self, config: Optional[_store.UruvConfig] = None, *,
                 backend: Optional[str] = None,
                 policy: Optional[LifecyclePolicy] = None):
        self.config = config or _store.UruvConfig()
        self.backend = backend
        self.policy = policy if policy is not None \
            else _lifecycle.DEFAULT_POLICY
        self.stats = _new_stats()
        # durability sidecar (repro.durability.recovery.Durability),
        # attached by Uruv(durable_dir=...) / Uruv.recover(): every
        # committed plan is logged to the WAL before its result reaches
        # the caller (DESIGN.md Sec 14)
        self.durability = None

    # ------------------------------------------------------------- lifecycle
    def create(self):
        return _store.create(self.config)

    def ts(self, store) -> int:
        return int(np.asarray(store.ts))

    def grow(self, store, *, leaves: bool = False, versions: bool = False,
             tracker: bool = False):
        self.stats["grows"] += 1
        return _lifecycle.grow(store, leaves=leaves, versions=versions,
                               tracker=tracker)

    def maintain(self, store, budget: Optional[int] = None, *,
                 phase: int = 0):
        store, reclaimed, merged = _lifecycle.maintain(
            store,
            budget if budget is not None else self.policy.maintain_budget,
            phase=phase,
        )
        self.stats["maintain_passes"] += 1
        self.stats["leaves_reclaimed"] += reclaimed
        return store, reclaimed, merged

    def _lifecycle_tick(self, store):
        """Post-apply lifecycle interleave: proactive growth ahead of the
        allocator wall, plus a bounded maintain burst on the frozen-
        fraction trigger (both policy-gated; results are unaffected)."""
        return _lifecycle.lifecycle_tick(
            store, self.policy, stats=self.stats,
            grow_fn=lambda st: self.grow(st, leaves=True),
        )

    # ----------------------------------------------------------------- write
    def apply(self, store, batch: OpBatch, *, light_path: bool = True,
              range_opts: RangeOptions = RangeOptions()):
        base = int(np.asarray(store.ts)) \
            if self.durability is not None else 0
        store, values, range_pages = _batch.apply_mixed(
            store, batch.codes, batch.keys, batch.values,
            light_path=light_path, backend=self.backend,
            max_results=range_opts.max_results,
            scan_leaves=range_opts.scan_leaves,
            max_rounds=range_opts.max_rounds,
            stats=self.stats, policy=self.policy,
        )
        store = self._lifecycle_tick(store)
        if self.durability is not None and len(batch):
            # log-on-commit: apply_mixed either applied the WHOLE plan or
            # raised — a logged record is a committed plan, and it hits
            # the WAL before the caller ever sees the result (the sync
            # half of the confirm-after-fsync contract; the pipelined
            # half is Uruv.confirm).  fsync cadence is the sidecar's
            # group-commit window (1 = every plan).
            self.durability.log_plan(
                base, np.asarray(batch.codes), np.asarray(batch.keys),
                np.asarray(batch.values))
        k2 = np.asarray(batch.values)
        range_items = [(pos, page, int(k2[pos])) for pos, page in range_pages]
        return store, values, range_items

    @device_pass(static=("donate_store",))
    def apply_nowait(self, store, batch: OpBatch, *,
                     donate_store: bool = False):
        """Dispatch ONE fast-path pass for a CRUD-only plan and return
        ``(store, values, ok)`` with the result and accept flag still
        device-resident — zero host syncs (the serving pipeline's deferred
        ``block_until_ready``; DESIGN.md Sec 12).  ``donate_store``
        donates the pools into the pass — the pipeline's in-place double
        buffer, exclusive-owner mode only.  Rejection handling (slow
        path, lifecycle) is the caller's, via ``Uruv.confirm`` + replay
        through :meth:`apply`.
        """
        self.stats["device_passes"] += 1
        return _store.bulk_apply(
            store, jnp.asarray(batch.codes), jnp.asarray(batch.keys),
            jnp.asarray(batch.values), backend=self.backend,
            donate_store=donate_store,
        )

    def lifecycle_tick(self, store):
        """Run the policy's proactive grow/maintain triggers now (the
        serving pipeline calls this between plans, off the latency path)."""
        return self._lifecycle_tick(store)

    # ------------------------------------------------------------------ read
    def lookup(self, store, keys, snap_ts):
        self.stats["device_passes"] += 1
        return _store.bulk_lookup(
            store, jnp.asarray(keys, jnp.int32),
            jnp.asarray(snap_ts, jnp.int32), backend=self.backend,
        )

    def range_page(self, store, k1s, k2s, snap_ts,
                   opts: RangeOptions = RangeOptions()) -> RangePage:
        self.stats["device_passes"] += 1
        keys, vals, cnt, trunc, resume = _store.bulk_range(
            store, np.atleast_1d(np.asarray(k1s, np.int32)),
            np.atleast_1d(np.asarray(k2s, np.int32)), snap_ts,
            max_results=opts.max_results, scan_leaves=opts.scan_leaves,
            max_rounds=opts.max_rounds, backend=self.backend,
        )
        return RangePage(keys, vals, cnt, trunc, resume)

    def scan_page(self, store, k1: int, k2: int, snap_ts,
                  *, max_scan_leaves: int = 64,
                  max_results: int = 1024) -> RangePage:
        """The paper's single-interval bounded RANGEQUERY pass (exactly
        ``max_scan_leaves`` leaves — the seed contract), as a Q=1 page."""
        self.stats["device_passes"] += 1
        keys, vals, cnt, trunc = _store.range_query(
            store, k1, k2, snap_ts,
            max_scan_leaves=max_scan_leaves, max_results=max_results,
            backend=self.backend,
        )
        # resume frontier: last kept key + 1 when the page has hits (never
        # skips overflow-dropped hits); a truncated ZERO-hit page (all
        # scanned keys dead at this snapshot) resumes at the first
        # unscanned leaf's separator — resuming at k1 would livelock
        i32 = jnp.int32
        sep = _store.scan_resume_sep(store, k1, max_scan_leaves, k2)
        c = jnp.maximum(cnt - 1, 0)
        resume = jnp.where(
            cnt > 0, keys[c] + 1,
            jnp.where(trunc, sep, jnp.asarray(k1, i32)),
        )
        return RangePage(keys[None], vals[None], cnt[None], trunc[None],
                         resume[None])

    def range_all(self, store, k1s, k2s, snap_ts,
                  opts: RangeOptions = RangeOptions()
                  ) -> List[List[Tuple[int, int]]]:
        return _batch.bulk_range_all(
            store, k1s, k2s, snap_ts,
            max_results=opts.max_results, scan_leaves=opts.scan_leaves,
            max_rounds=opts.max_rounds, backend=self.backend,
            stats=self.stats,
        )

    # --------------------------------------------------------- snapshots, GC
    def snapshot(self, store):
        # proactive tracker growth: a full ring would silently drop the
        # registration (OFLOW_TRACKER) — grow it first instead
        if (self.policy.auto_grow
                and int(np.asarray(store.trk_active).sum())
                >= store.cfg.tracker_cap):
            store = self.grow(store, tracker=True)
        store, ts = _store.snapshot(store)
        return store, int(ts)

    def release(self, store, snap_ts: int):
        return _store.release(store, snap_ts)

    def compact(self, store):
        self.stats["compactions"] += 1
        store, n_live = _store.compact(store)
        return store, int(n_live)

    def reindex(self, store):
        """Stop-the-world index repack (OFLOW_INDEX recovery / defrag);
        results are unchanged by construction (DESIGN.md Sec 11)."""
        self.stats["reindexes"] = self.stats.get("reindexes", 0) + 1
        return _store.reindex(store)


class ShardedExecutor:
    """Key-range-partitioned execution over a mesh axis (``core.sharded``).

    Wraps the jitted SPMD factories — ``make_apply`` (replicated announce),
    ``make_routed_apply`` (all_to_all routed announce, used first when the
    global width divides the shard count) and ``make_range_apply`` (per-
    shard bulk_range + on-device frontier-clamped merge) — behind the same
    executor contract as `LocalExecutor`, so `Uruv` callers never branch
    on topology.  Linearization is bit-identical to single-device
    execution including version timestamps (per-op global timestamps +
    the replicated clock; DESIGN.md Sec 3/8).

    Lifecycle decisions are REPLICATED across shards by construction: the
    stacked store has one shape, so ``grow`` doubles every shard's pools
    in the same device call and ``maintain`` runs vmapped over all shards
    — shard shapes can never diverge, and because lifecycle passes touch
    neither the clock nor version timestamps, sharded execution stays
    bit-identical to local execution even when the two interleave
    different grow/maintain schedules.  Capacity rejections relieve the
    flagged pool (maintain burst / doubling / tracker-gated compact) and
    retry, bounded by ``MAX_SLOWPATH_ROUNDS``; with ``auto_grow=False``
    a fully-rejected announce raises ``CapacityError`` (size shards for
    the working set — there is no sharded halving slow path).
    """

    def __init__(self, config: _sharded.ShardedConfig, mesh, *,
                 route_factor: int = 2, routed: bool = True,
                 policy: Optional[LifecyclePolicy] = None):
        self.config = config
        self.mesh = mesh
        self.n_shards = mesh.shape[config.axis_name]
        self.route_factor = route_factor
        self.routed = routed
        self.policy = policy if policy is not None \
            else _lifecycle.DEFAULT_POLICY
        self.stats = _new_stats()
        # SPMD factories are built lazily, cached per static config
        # (light_path for the apply passes, RangeOptions for range)
        self._apply_fns: Dict[bool, object] = {}
        self._routed_fns: Dict[bool, object] = {}
        self._lookup_fn = None
        self._range_fns: Dict[RangeOptions, object] = {}

    # ------------------------------------------------------------- lifecycle
    def create(self):
        return _sharded.create(self.config, self.mesh)

    def ts(self, store) -> int:
        return _sharded.global_ts(store)

    def _set_ts(self, store, ts: int):
        return dataclasses.replace(
            store, ts=jnp.full_like(store.ts, np.int32(ts))
        )

    def _reshard(self, store):
        """Pin a lifecycle-produced store back to the mesh sharding (grow /
        vmapped maintain can leave leaves with inferred placements)."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        return jax.device_put(
            store, NamedSharding(self.mesh, P(self.config.axis_name))
        )

    def grow(self, store, *, leaves: bool = False, versions: bool = False,
             tracker: bool = False):
        """Double the selected pools on EVERY shard (one stacked device
        op; shard shapes stay equal — the replicated-decision rule)."""
        self.stats["grows"] += 1
        return self._reshard(_lifecycle.grow(
            store, leaves=leaves, versions=versions, tracker=tracker,
        ))

    def maintain(self, store, budget: Optional[int] = None, *,
                 phase: int = 0):
        """One vmapped incremental maintenance pass over all shards."""
        store, reclaimed, merged = _lifecycle.maintain(
            store,
            budget if budget is not None else self.policy.maintain_budget,
            phase=phase,
        )
        self.stats["maintain_passes"] += 1
        self.stats["leaves_reclaimed"] += reclaimed
        return self._reshard(store), reclaimed, merged

    def _lifecycle_tick(self, store):
        def maintain_fn(st, budget, phase):
            st, rec, mer = _lifecycle.maintain(st, budget, phase=phase)
            return self._reshard(st), rec, mer

        return _lifecycle.lifecycle_tick(
            store, self.policy, stats=self.stats,
            grow_fn=lambda st: self.grow(st, leaves=True),
            maintain_fn=maintain_fn,
        )

    # ----------------------------------------------------------------- write
    def _apply_crud(self, store, codes, keys, values, light_path: bool):
        """One CRUD segment; timestamps come from the replicated clock
        (``store.ts``, restated after range segments by the shared
        apply_mixed loop), so op i of the segment runs at the global
        ``store.ts + i``.  Capacity rejections relieve pressure on every
        shard at once (the stacked pools share one shape) and retry —
        lifecycle steps never move the clock, so the retried pass applies
        at exactly the rejected pass's timestamps."""
        for _ in range(_batch.MAX_SLOWPATH_ROUNDS):
            apply_fn = self._apply_fns.get(light_path)
            if apply_fn is None:
                apply_fn = _sharded.make_apply(self.config, self.mesh,
                                               light_path=light_path)
                self._apply_fns[light_path] = apply_fn
            routed = None
            if self.routed and len(codes) % self.n_shards == 0:
                routed = self._routed_fns.get(light_path)
                if routed is None:
                    routed = _sharded.make_routed_apply(
                        self.config, self.mesh,
                        route_factor=self.route_factor,
                        light_path=light_path,
                    )
                    self._routed_fns[light_path] = routed
            try:
                store, res = _sharded.sharded_apply_batch(
                    store, codes, keys, values,
                    apply_fn=apply_fn, routed_fn=routed, stats=self.stats,
                )
                return store, np.asarray(res)
            except RuntimeError as e:    # full rejection: relieve + retry
                reason = getattr(e, "oflow_reason", 0)
                grow_bits = reason & (_store.OFLOW_LEAVES
                                      | _store.OFLOW_VERSIONS)
                index_bit = reason & _store.OFLOW_INDEX
                # reindex is reclamation, not growth: allowed under every
                # policy; pool doubling stays behind auto_grow
                if not (index_bit or (self.policy.auto_grow and grow_bits)):
                    raise CapacityError(str(e), store=store,
                                        oflow=reason) from e
                self.stats["slow_path_rounds"] += 1
                relief = index_bit | (
                    grow_bits if self.policy.auto_grow else 0)
                store = self._reshard(_lifecycle.relieve_pressure(
                    store, relief, len(codes), self.policy,
                    stats=self.stats,
                ))
        raise CapacityError(
            "sharded capacity retries failed to converge", store=store,
        )

    def apply(self, store, batch: OpBatch, *, light_path: bool = True,
              range_opts: RangeOptions = RangeOptions()):
        # ONE copy of the announce-segmentation semantics: the shared
        # core.batch.apply_mixed loop, with the sharded SPMD passes as its
        # hooks (a CRUD segment's timestamps derive from the replicated
        # clock, which the loop restates after every range segment)
        store, values, range_pages = _batch.apply_mixed(
            store, batch.codes, batch.keys, batch.values,
            crud_fn=lambda st, c, k, v, op_ts, next_ts:
                self._apply_crud(st, c, k, v, light_path),
            range_all_fn=lambda st, k1, k2, snaps:
                self.range_all(st, k1, k2, snaps, range_opts),
            get_ts_fn=self.ts,
            set_ts_fn=self._set_ts,
        )
        store = self._lifecycle_tick(store)
        k2 = np.asarray(batch.values)
        range_items = [(pos, page, int(k2[pos])) for pos, page in range_pages]
        return store, values, range_items

    def apply_nowait(self, store, batch: OpBatch, *,
                     donate_store: bool = False):
        """Sharded passes route/collect on the host, so a deferred-sync
        dispatch is not available; the coalescer detects this and falls
        back to coalesced synchronous :meth:`apply` plans."""
        raise NotImplementedError(
            "apply_nowait is single-device only; use apply()")

    def lifecycle_tick(self, store):
        return self._lifecycle_tick(store)

    # ------------------------------------------------------------------ read
    def lookup(self, store, keys, snap_ts):
        if self._lookup_fn is None:
            _, self._lookup_fn, _ = _sharded.make_ops(self.config, self.mesh)
        self.stats["device_passes"] += 1
        keys = jnp.atleast_1d(jnp.asarray(keys, jnp.int32))
        return self._lookup_fn(store, keys, jnp.asarray(snap_ts, jnp.int32))

    def range_page(self, store, k1s, k2s, snap_ts,
                   opts: RangeOptions = RangeOptions()) -> RangePage:
        k1 = np.atleast_1d(np.asarray(k1s, np.int32))
        k2 = np.atleast_1d(np.asarray(k2s, np.int32))
        snaps = np.broadcast_to(np.asarray(snap_ts, np.int32), k1.shape)
        fn = self._range_fns.get(opts)
        if fn is None:
            fn = _sharded.make_range_apply(
                self.config, self.mesh, max_results=opts.max_results,
                scan_leaves=opts.scan_leaves, max_rounds=opts.max_rounds,
            )
            self._range_fns[opts] = fn
        self.stats["device_passes"] += 1
        keys, vals, cnt, trunc, resume = fn(
            store, jnp.asarray(k1), jnp.asarray(k2), jnp.asarray(snaps)
        )
        return RangePage(keys, vals, cnt, trunc, resume)

    def scan_page(self, store, k1: int, k2: int, snap_ts, *,
                  max_scan_leaves: int = 64,
                  max_results: int = 1024) -> RangePage:
        opts = RangeOptions(max_results=max_results,
                            scan_leaves=max_scan_leaves, max_rounds=1)
        return self.range_page(store, [k1], [k2], snap_ts, opts)

    def range_all(self, store, k1s, k2s, snap_ts,
                  opts: RangeOptions = RangeOptions()
                  ) -> List[List[Tuple[int, int]]]:
        """Complete Q-interval answers: the shared ``bulk_range_all``
        pagination loop (power-of-two active-set compaction, exact resume)
        driven by the sharded all_gather-merged bounded pass."""
        def page_fn(st, lo, hi, sn):
            page = self.range_page(st, lo, hi, sn, opts)
            return (page.keys, page.values, page.count, page.truncated,
                    page.resume_k1)

        return _batch.bulk_range_all(store, k1s, k2s, snap_ts,
                                     page_fn=page_fn)

    # --------------------------------------------------------- snapshots, GC
    def snapshot(self, store):
        if (self.policy.auto_grow
                and int(np.asarray(store.trk_active)[0].sum())
                >= store.cfg.tracker_cap):
            store = self.grow(store, tracker=True)   # replicated ring is full
        store, snap = _sharded.sharded_snapshot(store)
        return store, int(snap)

    def release(self, store, snap_ts: int):
        return _sharded.sharded_release(store, snap_ts)

    def compact(self, store):
        self.stats["compactions"] += 1
        store, n_live = jax.vmap(_store.compact)(store)
        return store, int(np.asarray(n_live).sum())

    def reindex(self, store):
        """Repack every shard's index in one stacked pass (replicated
        decision: shard shapes stay equal, results unchanged)."""
        self.stats["reindexes"] = self.stats.get("reindexes", 0) + 1
        return self._reshard(_store.reindex(store))
