"""Config system: architecture configs, input shapes, runtime options.

Every assigned architecture has a module in ``repro.configs`` exposing
``config() -> ArchConfig`` with the exact published hyper-parameters, plus
``ArchConfig.reduced()`` for CPU smoke tests.  Shapes below are the assigned
input-shape set; applicability rules (decode for encoder-only, long-context
for full-attention archs) live in ``shape_applicable``.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# sub-configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int            # routed experts
    top_k: int
    d_expert: int             # per-expert FFN hidden size
    n_shared: int = 0         # always-on shared experts (deepseek-moe)
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3
    balance_coef: float = 1e-2


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    # layers are grouped [m, m, ..., m, s] with group_size = m_per_group + 1
    m_per_group: int = 7      # 7:1 mLSTM:sLSTM (paper's xLSTM[7:1])
    proj_factor: float = 2.0  # mLSTM up-projection
    d_conv: int = 4
    head_dim: int = 256


@dataclasses.dataclass(frozen=True)
class VLMConfig:
    patch_dim: int = 3200     # InternViT-6B feature dim (stubbed frontend)
    n_patches: int = 256


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str               # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0         # 0 -> d_model // n_heads
    # attention flavour
    rope_theta: float = 500000.0
    qk_norm: bool = False
    window: int = 0                  # sliding-window size (0 = full)
    global_every: int = 0            # gemma3: every k-th layer is global
    norm: str = "rmsnorm"            # rmsnorm | layernorm_np (olmo)
    act: str = "silu"                # silu | gelu
    tie_embeddings: bool = True
    encoder_only: bool = False       # hubert
    # family extensions
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    vlm: Optional[VLMConfig] = None
    hybrid_attn_every: int = 0       # zamba2 shared attention period
    # runtime
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    accum_steps: int = 1             # grad-accum microbatches inside a step
    sequence_parallel: bool = True   # shard residual stream seq over model axis
    use_pallas: bool = False         # Pallas kernels (TPU deploy); XLA otherwise

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        small_moe = (
            dataclasses.replace(self.moe, n_experts=4, top_k=2, d_expert=32,
                                n_shared=min(self.moe.n_shared, 1))
            if self.moe else None
        )
        small_ssm = (
            dataclasses.replace(self.ssm, d_state=8, head_dim=8, chunk=16)
            if self.ssm else None
        )
        small_xl = (
            dataclasses.replace(self.xlstm, m_per_group=3, head_dim=16)
            if self.xlstm else None
        )
        small_vlm = (
            dataclasses.replace(self.vlm, patch_dim=24, n_patches=4)
            if self.vlm else None
        )
        if self.xlstm is not None:
            n_layers = 4  # one group of (3 mLSTM + 1 sLSTM)
        elif self.hybrid_attn_every:
            n_layers = 4
        else:
            n_layers = 2
        return dataclasses.replace(
            self,
            n_layers=n_layers,
            d_model=32,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=8,
            d_ff=64 if self.d_ff else 0,
            vocab=128,
            window=min(self.window, 8) if self.window else 0,
            moe=small_moe,
            ssm=small_ssm,
            xlstm=small_xl,
            vlm=small_vlm,
            hybrid_attn_every=2 if self.hybrid_attn_every else 0,
            accum_steps=1,
            param_dtype="float32",
            compute_dtype="float32",
        )


# ---------------------------------------------------------------------------
# input shapes (assigned): seq_len x global_batch, and which step they lower
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "llama3_2_1b",
    "qwen3_4b",
    "olmo_1b",
    "gemma3_1b",
    "internvl2_76b",
    "zamba2_2_7b",
    "hubert_xlarge",
    "olmoe_1b_7b",
    "deepseek_moe_16b",
    "xlstm_350m",
]


def get_arch(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.config()


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(applicable, reason-if-not). DESIGN.md Sec 6 skip rules."""
    if arch.encoder_only and shape.kind == "decode":
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k":
        sub_quadratic = (
            arch.ssm is not None
            or arch.xlstm is not None
            or (arch.window > 0)  # local attention (gemma3 5:1) caps the window
        )
        if not sub_quadratic:
            return False, "pure full-attention arch: 500k needs sub-quadratic attention"
    return True, ""
