"""Pallas TPU kernel: snapshot version resolution (the paper's read()/vCAS).

Per query key, walk its descending-ts version chain until the first version
with ``ts <= snap`` (paper Sec 3.4 RANGEQUERY / Appendix D read).  Chains are
short by construction (compact() bounds retention), so the walk is a fixed
``max_chain`` unroll of *vectorized gathers*: the whole version pool
(ts/next/value, 12 B per entry — 768 KiB at the default 64 Ki entries) is
pinned in VMEM while query tiles stream through, so every chain step is a
VMEM-latency gather instead of an HBM round-trip.  That is the TPU analogue
of the paper's pointer walk staying in L1/L2.

Hardware note (DESIGN.md Sec 2): vectorized dynamic gather from VMEM lowers
via Mosaic's dynamic-gather on current TPU toolchains; this container
validates the kernel in interpret mode, and ops.py exposes the XLA-gather
oracle as the portable fallback path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.ref import NOT_FOUND, TOMBSTONE

from repro.analysis.marks import device_pass


def _vread_kernel(vh_ref, snap_ref, ts_ref, nxt_ref, val_ref, out_ref, *, max_chain):
    cur = vh_ref[...]                       # [BQ]
    snap = snap_ref[...]                    # [BQ]
    ts_tab = ts_ref[...]                    # [MV] (VMEM resident)
    nxt_tab = nxt_ref[...]
    val_tab = val_ref[...]
    for _ in range(max_chain):
        safe = jnp.maximum(cur, 0)
        ts_c = ts_tab[safe]
        adv = (cur >= 0) & (ts_c > snap)
        cur = jnp.where(adv, nxt_tab[safe], cur)
    safe = jnp.maximum(cur, 0)
    ok = (cur >= 0) & (ts_tab[safe] <= snap)
    val = jnp.where(ok, val_tab[safe], NOT_FOUND)
    out_ref[...] = jnp.where(val == TOMBSTONE, NOT_FOUND, val)


@device_pass(static=("max_chain", "block_q", "interpret"))
@functools.partial(
    jax.jit, static_argnames=("max_chain", "block_q", "interpret")
)
def versioned_read(
    vhead: jax.Array,
    snap_ts: jax.Array,
    ver_ts: jax.Array,
    ver_next: jax.Array,
    ver_value: jax.Array,
    *,
    max_chain: int = 16,
    block_q: int = 256,
    interpret: bool = True,
) -> jax.Array:
    P = vhead.shape[0]
    MV = ver_ts.shape[0]
    bq = min(block_q, P)
    pad = (-P) % bq
    vh = jnp.pad(vhead, (0, pad), constant_values=-1)
    sn = jnp.pad(jnp.broadcast_to(snap_ts, vhead.shape), (0, pad))
    out = pl.pallas_call(
        functools.partial(_vread_kernel, max_chain=max_chain),
        grid=((P + pad) // bq,),
        in_specs=[
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((MV,), lambda i: (0,)),
            pl.BlockSpec((MV,), lambda i: (0,)),
            pl.BlockSpec((MV,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bq,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((P + pad,), jnp.int32),
        interpret=interpret,
    )(vh, sn, ver_ts, ver_next, ver_value)
    return out[:P]
