"""Public wrapper: Pallas kernel with XLA-oracle fallback."""

from __future__ import annotations

import functools

import jax

from repro.kernels.versioned_read.versioned_read import versioned_read
from repro.kernels.versioned_read.ref import versioned_read_ref

from repro.analysis.marks import device_pass


@device_pass(static=("max_chain", "use_pallas", "interpret"))
@functools.partial(
    jax.jit, static_argnames=("max_chain", "use_pallas", "interpret")
)
def resolve(
    vhead, snap_ts, ver_ts, ver_next, ver_value,
    *, max_chain: int = 16, use_pallas: bool = True, interpret: bool = True,
):
    if use_pallas:
        return versioned_read(
            vhead, snap_ts, ver_ts, ver_next, ver_value,
            max_chain=max_chain, interpret=interpret,
        )
    return versioned_read_ref(
        vhead, snap_ts, ver_ts, ver_next, ver_value, max_chain=max_chain
    )
