"""Pure-jnp oracle for versioned_read (bounded chain walk via XLA gather)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.ref import NOT_FOUND, TOMBSTONE


@functools.partial(jax.jit, static_argnames=("max_chain",))
def versioned_read_ref(
    vhead, snap_ts, ver_ts, ver_next, ver_value, *, max_chain: int = 16
):
    snap = jnp.broadcast_to(snap_ts, vhead.shape)
    cur = vhead
    for _ in range(max_chain):
        safe = jnp.maximum(cur, 0)
        adv = (cur >= 0) & (ver_ts[safe] > snap)
        cur = jnp.where(adv, ver_next[safe], cur)
    safe = jnp.maximum(cur, 0)
    ok = (cur >= 0) & (ver_ts[safe] <= snap)
    val = jnp.where(ok, ver_value[safe], NOT_FOUND)
    return jnp.where(val == TOMBSTONE, NOT_FOUND, val)
