"""Pure-jnp oracle for the uruv_search kernels."""

from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def search_positions_ref(dir_keys: jax.Array, queries: jax.Array) -> jax.Array:
    pos = jnp.searchsorted(dir_keys, queries, side="right").astype(jnp.int32) - 1
    return jnp.maximum(pos, 0)


@jax.jit
def leaf_slots_ref(rows: jax.Array, queries: jax.Array):
    L = rows.shape[1]
    slot = jnp.sum(rows < queries[:, None], axis=1).astype(jnp.int32)
    hit = jnp.take_along_axis(rows, jnp.minimum(slot, L - 1)[:, None], axis=1)[:, 0]
    exists = (slot < L) & (hit == queries)
    return slot, exists
