"""Pure-jnp oracle for the uruv_search kernels."""

from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def search_positions_ref(dir_keys: jax.Array, queries: jax.Array) -> jax.Array:
    pos = jnp.searchsorted(dir_keys, queries, side="right").astype(jnp.int32) - 1
    return jnp.maximum(pos, 0)


@jax.jit
def index_descend_ref(level_keys, level_child, queries: jax.Array):
    """Pure-jnp oracle for the multi-level descent kernel: returns
    (bottom_node, bottom_slot, leaf_id) of the last separator <= q."""
    i32 = jnp.int32
    q = jnp.asarray(queries, i32)
    cur = jnp.zeros_like(q)
    slot = jnp.zeros_like(q)
    nxt = cur
    depth = len(level_keys)
    from repro.core.ref import KEY_MAX

    for l in range(depth - 1, -1, -1):
        rows = level_keys[l][cur]
        slot = jnp.maximum(
            jnp.sum(((rows <= q[:, None]) & (rows < KEY_MAX)).astype(i32),
                    axis=1) - 1, 0)
        nxt = jnp.take_along_axis(
            level_child[l][cur], slot[:, None], axis=1)[:, 0]
        if l > 0:
            cur = nxt
    return cur, slot, nxt


@jax.jit
def leaf_slots_ref(rows: jax.Array, queries: jax.Array):
    L = rows.shape[1]
    slot = jnp.sum(rows < queries[:, None], axis=1).astype(jnp.int32)
    hit = jnp.take_along_axis(rows, jnp.minimum(slot, L - 1)[:, None], axis=1)[:, 0]
    exists = (slot < L) & (hit == queries)
    return slot, exists
