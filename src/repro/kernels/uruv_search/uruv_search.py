"""Pallas TPU kernel: batched B+-tree descent (the paper's traversal).

The hot loop of every Uruv ADT op is the root->leaf descent (paper Fig. 1,
line 18: "binary search over curr's keys").  In the TPU-native store the
internal index is the sorted leaf directory; locating a key is computing its
*rank* in that directory.  A pointer-chasing binary search is hostile to the
TPU (serial, scalar); the roofline-optimal formulation is a **blocked
compare-reduce**:

    pos(q) = (# directory keys <= q) - 1

streamed over directory tiles held in VMEM while a tile of queries sits in
VREGs — O(P·ML) cheap VPU compares, perfectly vectorized, directory read
from HBM exactly once per query block.  For ML = 4096 int32 separators a
whole directory tile burst is 16 KiB — far under the ~16 MiB VMEM budget, so
the kernel is compute-light and bandwidth-bound on the query stream, which
is the right trade at the leaf counts Uruv serves (see DESIGN.md Sec 7).

A second tiny kernel computes the in-leaf slot (rank within a gathered leaf
row) for the batch — the paper's in-leaf linear search, vectorized.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.ref import KEY_MAX

from repro.analysis.marks import device_pass


def _search_kernel(dir_ref, q_ref, pos_ref, acc_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    d = dir_ref[...]                      # [BD]  directory tile (VMEM)
    q = q_ref[...]                        # [BQ]  query tile
    # rank contribution of this directory tile
    acc_ref[...] += jnp.sum(
        (d[None, :] <= q[:, None]).astype(jnp.int32), axis=1
    )

    @pl.when(j == pl.num_programs(1) - 1)
    def _flush():
        pos_ref[...] = acc_ref[...] - 1


@device_pass(static=("block_q", "block_dir", "interpret"))
@functools.partial(
    jax.jit, static_argnames=("block_q", "block_dir", "interpret")
)
def search_positions(
    dir_keys: jax.Array,
    queries: jax.Array,
    *,
    block_q: int = 256,
    block_dir: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """pos[i] = searchsorted(dir_keys, queries[i], side='right') - 1."""
    P = queries.shape[0]
    ML = dir_keys.shape[0]
    bq = min(block_q, P)
    bd = min(block_dir, ML)
    pad_p = (-P) % bq
    pad_d = (-ML) % bd
    q = jnp.pad(queries, (0, pad_p), constant_values=KEY_MAX - 1)
    d = jnp.pad(dir_keys, (0, pad_d), constant_values=KEY_MAX)

    pos = pl.pallas_call(
        _search_kernel,
        grid=((P + pad_p) // bq, (ML + pad_d) // bd),
        in_specs=[
            pl.BlockSpec((bd,), lambda i, j: (j,)),
            pl.BlockSpec((bq,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((bq,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct(((P + pad_p),), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bq,), jnp.int32)],
        interpret=interpret,
    )(d, q)
    return jnp.maximum(pos[:P], 0)


def _index_descend_kernel(q_ref, *refs, depth):
    """Blocked F-way descent over the multi-level fat-node index.

    ``refs`` carries (keys_l, child_l) for l = depth-1 .. 0 followed by
    the three outputs.  Every level's node pool is VMEM-resident (the
    whole index is ~ML/F * F ints = O(ML) — a fraction of the leaf pool);
    a query tile descends all levels with one dynamic row gather + one
    F-wide compare-reduce per level: O(P * F * depth) VPU compares
    instead of the flat O(P * ML) rank of the directory era.
    """
    node_ref, slot_ref, leaf_ref = refs[2 * depth:]
    q = q_ref[...]                        # [BQ]
    cur = jnp.zeros_like(q)               # root is node 0
    slot = jnp.zeros_like(q)
    nxt = cur
    for i in range(depth):                # level l = depth-1-i
        keys = refs[2 * i][...]           # [C_l, F]
        child = refs[2 * i + 1][...]
        rows = keys[cur]                  # [BQ, F] dynamic row gather
        # live entries only (KEY_MAX = padding; q may be a KEY_MAX sentinel)
        slot = jnp.maximum(
            jnp.sum(((rows <= q[:, None]) & (rows < KEY_MAX))
                    .astype(jnp.int32), axis=1) - 1, 0)
        nxt = jnp.take_along_axis(child[cur], slot[:, None], axis=1)[:, 0]
        if i < depth - 1:
            cur = nxt
    node_ref[...] = cur
    slot_ref[...] = slot
    leaf_ref[...] = nxt


@device_pass(static=("block_q", "interpret"))
@functools.partial(jax.jit, static_argnames=("block_q", "interpret"))
def index_descend(
    level_keys,            # tuple l=0..D-1 of int32 [C_l, F]
    level_child,           # tuple l=0..D-1 of int32 [C_l, F]
    queries: jax.Array,
    *,
    block_q: int = 256,
    interpret: bool = True,
):
    """Root->leaf descent: returns (bottom_node, bottom_slot, leaf_id)
    of the last separator <= q — the kernel twin of
    ``repro.core.index.descend``."""
    depth = len(level_keys)
    P = queries.shape[0]
    bq = min(block_q, P)
    pad = (-P) % bq
    q = jnp.pad(queries, (0, pad), constant_values=KEY_MAX - 1)
    tables = []
    in_specs = [pl.BlockSpec((bq,), lambda i: (i,))]
    for l in range(depth - 1, -1, -1):
        for t in (level_keys[l], level_child[l]):
            tables.append(t)
            in_specs.append(pl.BlockSpec(t.shape, lambda i: (0, 0)))
    out = pl.pallas_call(
        functools.partial(_index_descend_kernel, depth=depth),
        grid=((P + pad) // bq,),
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((bq,), lambda i: (i,))] * 3,
        out_shape=[jax.ShapeDtypeStruct((P + pad,), jnp.int32)] * 3,
        interpret=interpret,
    )(q, *tables)
    return out[0][:P], out[1][:P], out[2][:P]


def _slot_kernel(rows_ref, q_ref, slot_ref, exists_ref):
    rows = rows_ref[...]                  # [BQ, L]
    q = q_ref[...]                        # [BQ]
    slot = jnp.sum((rows < q[:, None]).astype(jnp.int32), axis=1)
    L = rows.shape[1]
    hit_idx = jnp.minimum(slot, L - 1)
    hit = jnp.take_along_axis(rows, hit_idx[:, None], axis=1)[:, 0]
    slot_ref[...] = slot
    exists_ref[...] = ((slot < L) & (hit == q)).astype(jnp.int32)


@device_pass(static=("block_q", "interpret"))
@functools.partial(jax.jit, static_argnames=("block_q", "interpret"))
def leaf_slots(
    rows: jax.Array,
    queries: jax.Array,
    *,
    block_q: int = 256,
    interpret: bool = True,
):
    """In-leaf rank + membership for pre-gathered leaf rows [P, L]."""
    P, L = rows.shape
    bq = min(block_q, P)
    pad = (-P) % bq
    rows_p = jnp.pad(rows, ((0, pad), (0, 0)), constant_values=KEY_MAX)
    q_p = jnp.pad(queries, (0, pad), constant_values=KEY_MAX - 1)
    slot, exists = pl.pallas_call(
        _slot_kernel,
        grid=((P + pad) // bq,),
        in_specs=[
            pl.BlockSpec((bq, L), lambda i: (i, 0)),
            pl.BlockSpec((bq,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((bq,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((P + pad,), jnp.int32),
            jax.ShapeDtypeStruct((P + pad,), jnp.int32),
        ],
        interpret=interpret,
    )(rows_p, q_p)
    return slot[:P], exists[:P].astype(bool)
