"""Jitted public wrappers for the uruv_search kernels.

``locate()`` is the full traversal contract (multi-level fat-node descent
-> leaf gather -> in-leaf slot), switchable between the Pallas path and
the XLA oracle.  The store routes through `repro.core.backend.locate`,
which auto-detects TPU (compiled Pallas) vs anything else (XLA) with a
`URUV_BACKEND` override; this module remains the kernel-level entry used
by the interpret-mode sweeps (see DESIGN.md Sec 7 / Sec 11).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.uruv_search.uruv_search import index_descend, leaf_slots
from repro.kernels.uruv_search.ref import index_descend_ref, leaf_slots_ref

from repro.analysis.marks import device_pass


@device_pass(static=("use_pallas", "interpret"))
@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def locate(
    level_keys,            # tuple l=0..D-1 of int32 [C_l, F] (bottom first)
    level_child,           # tuple l=0..D-1 of int32 [C_l, F]
    leaf_keys: jax.Array,
    queries: jax.Array,
    *,
    use_pallas: bool = True,
    interpret: bool = True,
):
    """Returns (bottom_node, bottom_slot, leaf_id, slot, exists)."""
    if use_pallas:
        bnode, bslot, leaf_id = index_descend(
            tuple(level_keys), tuple(level_child), queries,
            interpret=interpret)
    else:
        bnode, bslot, leaf_id = index_descend_ref(
            tuple(level_keys), tuple(level_child), queries)
    rows = leaf_keys[leaf_id]
    if use_pallas:
        slot, exists = leaf_slots(rows, queries, interpret=interpret)
    else:
        slot, exists = leaf_slots_ref(rows, queries)
    return bnode, bslot, leaf_id, slot, exists
