"""Jitted public wrappers for the uruv_search kernels.

``locate()`` is the full traversal contract (directory rank -> leaf gather
-> in-leaf slot), switchable between the Pallas path and the XLA oracle.
The store routes through `repro.core.backend.locate`, which auto-detects
TPU (compiled Pallas) vs anything else (XLA) with a `URUV_BACKEND`
override; this module remains the kernel-level entry used by the
interpret-mode sweeps (see DESIGN.md Sec 3.3 / Sec 7).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.uruv_search.uruv_search import leaf_slots, search_positions
from repro.kernels.uruv_search.ref import leaf_slots_ref, search_positions_ref


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def locate(
    dir_keys: jax.Array,
    dir_leaf: jax.Array,
    leaf_keys: jax.Array,
    queries: jax.Array,
    *,
    use_pallas: bool = True,
    interpret: bool = True,
):
    """Returns (dir_pos, leaf_id, slot, exists) for a query batch."""
    if use_pallas:
        pos = search_positions(dir_keys, queries, interpret=interpret)
    else:
        pos = search_positions_ref(dir_keys, queries)
    leaf_id = dir_leaf[pos]
    rows = leaf_keys[leaf_id]
    if use_pallas:
        slot, exists = leaf_slots(rows, queries, interpret=interpret)
    else:
        slot, exists = leaf_slots_ref(rows, queries)
    return pos, leaf_id, slot, exists
