"""Pure-jnp oracle for flash-decode."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


@jax.jit
def decode_attention_ref(q, k, v, lengths):
    """q [B,H,D], k/v [B,KVH,S,D], lengths [B] -> [B,H,D]."""
    B, H, D = q.shape
    _, KVH, S, _ = k.shape
    group = H // KVH
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32), kx.astype(jnp.float32))
    s = s / (D ** 0.5)
    mask = jnp.arange(S)[None, None, :] < lengths[:, None, None]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhk,bhkd->bhd", p, vx.astype(jnp.float32))
    return out.astype(q.dtype)
