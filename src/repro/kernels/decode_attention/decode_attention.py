"""Pallas TPU kernel: flash-decode — one query token vs a long KV cache.

Decode attention is memory-bound: per step the whole KV cache streams
HBM->VMEM once while compute is O(S·D) MACs per head.  The kernel therefore
optimizes for pure streaming:

  grid = (batch, kv_heads, Skv/BK)

with the (G, D) grouped-query tile (G = H/KVH q-heads sharing one kv head)
resident in VMEM scratch across the KV loop, online-softmax accumulation,
and per-sequence KV length masking (continuous batching serves ragged
cache lengths — lengths come from the Uruv page table, see repro.serve).

The same kernel is the shard-local body of the sequence-parallel decode
path: shards compute partial (m, l, acc) over their KV slice and the
combine is an all-reduce of rescaled partials (repro.models.attention).
This kernel returns (out, m, l) so the combine can be fused downstream.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.analysis.marks import device_pass

NEG_INF = -1e30


def _decode_kernel(
    len_ref, q_ref, k_ref, v_ref, o_ref, m_out_ref, l_out_ref,
    m_ref, l_ref, acc_ref, *, block_k, scale,
):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    k_start = ik * block_k
    kv_len = len_ref[0]
    relevant = k_start < kv_len

    @pl.when(relevant)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # [G, D]
        k = k_ref[0, 0].astype(jnp.float32)                  # [BK, D]
        v = v_ref[0, 0].astype(jnp.float32)                  # [BK, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                     # [G, BK]
        ki = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = ki < kv_len
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(ik == pl.num_programs(2) - 1)
    def _flush():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe).astype(o_ref.dtype)
        m_out_ref[0, 0] = m_ref[...]
        l_out_ref[0, 0] = l_ref[...]


@device_pass(static=("block_k", "interpret", "return_stats"))
@functools.partial(
    jax.jit, static_argnames=("block_k", "interpret", "return_stats")
)
def decode_attention(
    q: jax.Array,        # [B, H, D]   one new token per sequence
    k: jax.Array,        # [B, KVH, S, D]
    v: jax.Array,        # [B, KVH, S, D]
    lengths: jax.Array,  # [B] int32 — valid cache length per sequence
    *,
    block_k: int = 512,
    interpret: bool = True,
    return_stats: bool = False,
):
    B, H, D = q.shape
    _, KVH, S, _ = k.shape
    assert H % KVH == 0
    G = H // KVH
    scale = 1.0 / (D ** 0.5)
    qg = q.reshape(B, KVH, G, D)

    bk = min(block_k, S)
    pad_k = (-S) % bk
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))

    out, m, l = pl.pallas_call(
        functools.partial(_decode_kernel, block_k=bk, scale=scale),
        grid=(B, KVH, (S + pad_k) // bk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, j: (b,)),
            pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j: (b, h, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, G, 1), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, G, 1), lambda b, h, j: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, KVH, G, D), q.dtype),
            jax.ShapeDtypeStruct((B, KVH, G, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, KVH, G, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, kp, vp)
    out = out.reshape(B, H, D)
    if return_stats:
        return out, m.reshape(B, H, 1), l.reshape(B, H, 1)
    return out
