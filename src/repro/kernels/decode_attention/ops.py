"""Public decode-attention entry point: Pallas kernel or XLA oracle."""

from __future__ import annotations

import functools

import jax

from repro.kernels.decode_attention.decode_attention import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref

from repro.analysis.marks import device_pass


@device_pass(static=("use_pallas", "interpret", "block_k"))
@functools.partial(
    jax.jit, static_argnames=("use_pallas", "interpret", "block_k")
)
def decode(q, k, v, lengths, *, use_pallas=False, interpret=True, block_k=512):
    if use_pallas:
        return decode_attention(
            q, k, v, lengths, block_k=block_k, interpret=interpret
        )
    return decode_attention_ref(q, k, v, lengths)
