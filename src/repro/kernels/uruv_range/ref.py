"""Pure-jnp oracle for the uruv_range kernel (the `xla` backend path)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.ref import KEY_MAX, NOT_FOUND, TOMBSTONE


@functools.partial(jax.jit, static_argnames=("max_chain",))
def range_scan_ref(
    lids, pvalid, k1, k2, snap_ts,
    leaf_keys, leaf_vhead, leaf_count, ver_ts, ver_next, ver_value,
    *, max_chain: int = 16,
):
    """Same contract as uruv_range.range_scan: (cand_keys, cand_vals) [Q, S*L]."""
    Q, S = lids.shape
    L = leaf_keys.shape[1]
    rows = leaf_keys[lids]                                 # [Q, S, L]
    vhs = leaf_vhead[lids]
    cnt = leaf_count[lids]
    slot_ok = jnp.arange(L, dtype=jnp.int32)[None, None, :] < cnt[..., None]
    cand = (
        pvalid[..., None] & slot_ok
        & (rows >= k1[:, None, None]) & (rows <= k2[:, None, None])
    )
    cur = jnp.where(cand, vhs, -1)
    snap = jnp.broadcast_to(snap_ts[:, None, None], cur.shape)
    for _ in range(max_chain):
        safe = jnp.maximum(cur, 0)
        adv = (cur >= 0) & (ver_ts[safe] > snap)
        cur = jnp.where(adv, ver_next[safe], cur)
    safe = jnp.maximum(cur, 0)
    ok = (cur >= 0) & (ver_ts[safe] <= snap)
    val = jnp.where(ok, ver_value[safe], NOT_FOUND)
    val = jnp.where(val == TOMBSTONE, NOT_FOUND, val)
    hit = cand & (val != NOT_FOUND)
    cand_keys = jnp.where(hit, rows, KEY_MAX).reshape(Q, S * L)
    cand_vals = jnp.where(hit, val, NOT_FOUND).reshape(Q, S * L)
    return cand_keys, cand_vals
