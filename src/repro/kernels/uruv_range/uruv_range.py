"""Pallas TPU kernel: fused batched range scan (paper Sec 3.4 / Fig. 11).

One kernel answers the candidate phase of Q range queries at once: for each
query's window of leaf ids it gathers the leaf rows + version-chain heads,
masks the in-interval slots, and walks every candidate's version chain to
its snapshot — the leaf gather and ``versioned_read`` resolve of the
single-query path fused into one VMEM-resident pass.

Layout mirrors the other Uruv kernels (DESIGN.md Sec 7): the leaf pool
(``[ML, L]`` keys/vheads + ``[ML]`` counts) and the version pool
(ts/next/value) are pinned in VMEM while query tiles stream through, so a
chain step is a VMEM-latency gather instead of an HBM round-trip.  For the
default capacities that is ~1.3 MiB of tables — far under the ~16 MiB VMEM
budget.  The scan window loop (``scan_leaves``) and the chain walk
(``max_chain``) are static unrolls; compaction of hits into the per-query
result block stays in XLA (sort-based, see ``store.bulk_range``).

Hardware note: vectorized dynamic gather from VMEM lowers via Mosaic's
dynamic-gather on current TPU toolchains; this container validates the
kernel in interpret mode, and ref.py provides the pure-jnp oracle that the
``xla`` backend serves as the portable fallback.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.ref import KEY_MAX, NOT_FOUND, TOMBSTONE

from repro.analysis.marks import device_pass


def _range_kernel(
    lids_ref, pvalid_ref, k1_ref, k2_ref, snap_ref,
    lkeys_ref, lvh_ref, lcnt_ref, ts_ref, nxt_ref, val_ref,
    okeys_ref, ovals_ref, *, max_chain, scan_leaves,
):
    k1 = k1_ref[...]                       # [BQ]
    k2 = k2_ref[...]
    snap = snap_ref[...]
    lkeys = lkeys_ref[...]                 # [ML, L]   (VMEM resident)
    lvh = lvh_ref[...]
    lcnt = lcnt_ref[...]                   # [ML]
    ts_tab = ts_ref[...]                   # [MV]
    nxt_tab = nxt_ref[...]
    val_tab = val_ref[...]
    L = lkeys.shape[1]
    for s in range(scan_leaves):
        lid = lids_ref[:, s]               # [BQ] leaf ids for window slot s
        pv = pvalid_ref[:, s] != 0
        rows = lkeys[lid]                  # [BQ, L] leaf gather
        vhs = lvh[lid]
        cnt = lcnt[lid]
        slot_ok = jax.lax.broadcasted_iota(jnp.int32, rows.shape, 1) < cnt[:, None]
        cand = (
            pv[:, None] & slot_ok
            & (rows >= k1[:, None]) & (rows <= k2[:, None])
        )
        # fused versioned read: first version with ts <= snap per candidate
        cur = jnp.where(cand, vhs, -1)
        for _ in range(max_chain):
            safe = jnp.maximum(cur, 0)
            adv = (cur >= 0) & (ts_tab[safe] > snap[:, None])
            cur = jnp.where(adv, nxt_tab[safe], cur)
        safe = jnp.maximum(cur, 0)
        ok = (cur >= 0) & (ts_tab[safe] <= snap[:, None])
        val = jnp.where(ok, val_tab[safe], NOT_FOUND)
        val = jnp.where(val == TOMBSTONE, NOT_FOUND, val)
        hit = cand & (val != NOT_FOUND)
        okeys_ref[:, s * L:(s + 1) * L] = jnp.where(hit, rows, KEY_MAX)
        ovals_ref[:, s * L:(s + 1) * L] = jnp.where(hit, val, NOT_FOUND)


@device_pass(static=("max_chain", "block_q", "interpret"))
@functools.partial(
    jax.jit, static_argnames=("max_chain", "block_q", "interpret")
)
def range_scan(
    lids: jax.Array,       # int32 [Q, S]  leaf ids per query window slot
    pvalid: jax.Array,     # bool  [Q, S]  window slot participates
    k1: jax.Array,         # int32 [Q]
    k2: jax.Array,         # int32 [Q]
    snap_ts: jax.Array,    # int32 [Q]
    leaf_keys: jax.Array,  # int32 [ML, L]
    leaf_vhead: jax.Array,  # int32 [ML, L]
    leaf_count: jax.Array,  # int32 [ML]
    ver_ts: jax.Array,     # int32 [MV]
    ver_next: jax.Array,   # int32 [MV]
    ver_value: jax.Array,  # int32 [MV]
    *,
    max_chain: int = 16,
    block_q: int = 128,
    interpret: bool = True,
):
    """Candidate phase of Q range queries: (cand_keys, cand_vals) [Q, S*L].

    Non-hits are (KEY_MAX, NOT_FOUND); hits carry the key and its value
    resolved at the query's snapshot (tombstones already dropped).
    """
    Q, S = lids.shape
    ML, L = leaf_keys.shape
    MV = ver_ts.shape[0]
    bq = min(block_q, Q)
    pad = (-Q) % bq
    lids_p = jnp.pad(lids, ((0, pad), (0, 0)))
    pv_p = jnp.pad(pvalid.astype(jnp.int32), ((0, pad), (0, 0)))
    k1_p = jnp.pad(k1, (0, pad), constant_values=KEY_MAX - 1)
    k2_p = jnp.pad(k2, (0, pad))
    sn_p = jnp.pad(snap_ts, (0, pad))

    okeys, ovals = pl.pallas_call(
        functools.partial(_range_kernel, max_chain=max_chain, scan_leaves=S),
        grid=((Q + pad) // bq,),
        in_specs=[
            pl.BlockSpec((bq, S), lambda i: (i, 0)),
            pl.BlockSpec((bq, S), lambda i: (i, 0)),
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((ML, L), lambda i: (0, 0)),
            pl.BlockSpec((ML, L), lambda i: (0, 0)),
            pl.BlockSpec((ML,), lambda i: (0,)),
            pl.BlockSpec((MV,), lambda i: (0,)),
            pl.BlockSpec((MV,), lambda i: (0,)),
            pl.BlockSpec((MV,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bq, S * L), lambda i: (i, 0)),
            pl.BlockSpec((bq, S * L), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q + pad, S * L), jnp.int32),
            jax.ShapeDtypeStruct((Q + pad, S * L), jnp.int32),
        ],
        interpret=interpret,
    )(lids_p, pv_p, k1_p, k2_p, sn_p,
      leaf_keys, leaf_vhead, leaf_count, ver_ts, ver_next, ver_value)
    return okeys[:Q], ovals[:Q]
