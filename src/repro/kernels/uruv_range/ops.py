"""Jitted public wrapper for the uruv_range kernel.

``range_scan()`` is the fused candidate-phase contract (leaf-window gather +
in-interval mask + versioned resolve), switchable between the Pallas path
and the pure-jnp oracle.  The store routes through
`repro.core.backend.range_scan` (xla | pallas | pallas_interpret, same
resolution as locate/resolve); this module remains the kernel-level entry
used by the interpret-mode parity sweeps (tests/test_kernels.py).
"""

from __future__ import annotations

import functools

import jax

from repro.kernels.uruv_range.ref import range_scan_ref
from repro.kernels.uruv_range.uruv_range import range_scan as range_scan_pallas

from repro.analysis.marks import device_pass


@device_pass(static=("max_chain", "block_q", "use_pallas", "interpret"))
@functools.partial(
    jax.jit, static_argnames=("max_chain", "block_q", "use_pallas", "interpret")
)
def range_scan(
    lids, pvalid, k1, k2, snap_ts,
    leaf_keys, leaf_vhead, leaf_count, ver_ts, ver_next, ver_value,
    *, max_chain: int = 16, block_q: int = 128, use_pallas: bool = True,
    interpret: bool = True,
):
    """(cand_keys, cand_vals) [Q, S*L]; non-hits are (KEY_MAX, NOT_FOUND)."""
    if use_pallas:
        return range_scan_pallas(
            lids, pvalid, k1, k2, snap_ts,
            leaf_keys, leaf_vhead, leaf_count, ver_ts, ver_next, ver_value,
            max_chain=max_chain, block_q=block_q, interpret=interpret,
        )
    return range_scan_ref(
        lids, pvalid, k1, k2, snap_ts,
        leaf_keys, leaf_vhead, leaf_count, ver_ts, ver_next, ver_value,
        max_chain=max_chain,
    )
