"""Pallas TPU kernel: blockwise (flash) attention for prefill/training.

Online-softmax attention with explicit BlockSpec VMEM tiling:

  grid = (batch, q_heads, Sq/BQ, Skv/BK)

Per grid step a (BQ, D) query tile and a (BK, D) key/value tile live in
VMEM; the (BQ, BK) score tile hits the MXU; running max / sum / accumulator
stay in VMEM scratch across the KV loop (innermost grid axis).  Supports:

  * causal masking,
  * sliding-window (gemma3-style local) masking,
  * GQA — the kv head for q-head h is h // (H // KVH), applied in the
    k/v BlockSpec index maps (no KV replication in HBM),
  * KV-length masking for padded sequences (static pad amount).

Block shapes default to (128, 128): MXU-aligned (multiples of 128 on both
matmul dims) and, at D = 128, a comfortable VMEM footprint of
~(BQ + 2·BK)·D·2 B + (BQ·BK)·4 B ≈ 160 KiB per step.

Fully-masked KV tiles (beyond the causal frontier or the sliding window)
are skipped via pl.when — the dominant prefill win for local-attention
layers: work per q tile drops from O(Skv) to O(window).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.analysis.marks import device_pass

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, causal, window, kv_len, block_q, block_k, scale,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * block_q
    k_start = ik * block_k
    # tile-level skip: strictly above causal diagonal or below window floor
    relevant = jnp.asarray(True)
    if causal:
        relevant &= k_start <= q_start + block_q - 1
    if window > 0:
        relevant &= k_start + block_k - 1 >= q_start - window + 1
    relevant &= k_start < kv_len

    @pl.when(relevant)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # [BQ, D]
        k = k_ref[0, 0].astype(jnp.float32)                # [BK, D]
        v = v_ref[0, 0].astype(jnp.float32)                # [BK, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                   # [BQ, BK]
        qi = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        ki = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = ki < kv_len
        if causal:
            mask &= qi >= ki
        if window > 0:
            mask &= qi - ki < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                 # [BQ, 1]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(ik == pl.num_programs(3) - 1)
    def _flush():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe).astype(o_ref.dtype)


@device_pass(static=("causal", "window", "block_q", "block_k", "interpret"))
@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,       # [B, H, Sq, D]
    k: jax.Array,       # [B, KVH, Skv, D]
    v: jax.Array,       # [B, KVH, Skv, D]
    *,
    causal: bool = True,
    window: int = 0,    # 0 = full attention; >0 = sliding window size
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    B, H, Sq, D = q.shape
    _, KVH, Skv, _ = k.shape
    assert H % KVH == 0, "GQA requires H % KVH == 0"
    group = H // KVH
    scale = 1.0 / (D ** 0.5)

    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    pad_q = (-Sq) % bq
    pad_k = (-Skv) % bk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel,
            causal=causal, window=window, kv_len=Skv,
            block_q=bq, block_k=bk, scale=scale,
        ),
        grid=(B, H, (Sq + pad_q) // bq, (Skv + pad_k) // bk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec(
                (1, 1, bk, D),
                lambda b, h, i, j, group=group: (b, h // group, j, 0),
            ),
            pl.BlockSpec(
                (1, 1, bk, D),
                lambda b, h, i, j, group=group: (b, h // group, j, 0),
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq + pad_q, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :Sq, :]
