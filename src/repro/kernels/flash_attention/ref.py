"""Pure-jnp dense attention oracle (causal / sliding-window / GQA)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def attention_ref(
    q: jax.Array,      # [B, H, Sq, D]
    k: jax.Array,      # [B, KVH, Skv, D]
    v: jax.Array,      # [B, KVH, Skv, D]
    *,
    causal: bool = True,
    window: int = 0,
) -> jax.Array:
    B, H, Sq, D = q.shape
    _, KVH, Skv, _ = k.shape
    group = H // KVH
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), kx.astype(jnp.float32)
    ) / (D ** 0.5)
    qi = jnp.arange(Sq)[:, None]
    ki = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= qi >= ki
    if window > 0:
        mask &= qi - ki < window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vx.astype(jnp.float32))
    return out.astype(q.dtype)
