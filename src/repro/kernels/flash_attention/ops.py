"""Public attention entry point: Pallas kernel or XLA oracle."""

from __future__ import annotations

import functools

import jax

from repro.analysis.marks import device_pass
from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


@device_pass(static=("causal", "window", "use_pallas", "interpret",
                     "block_q", "block_k"))
@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "use_pallas", "interpret",
                     "block_q", "block_k"),
)
def attention(
    q, k, v, *, causal=True, window=0,
    use_pallas=False, interpret=True, block_q=128, block_k=128,
):
    if use_pallas:
        return flash_attention(
            q, k, v, causal=causal, window=window,
            block_q=block_q, block_k=block_k, interpret=interpret,
        )
    return attention_ref(q, k, v, causal=causal, window=window)
