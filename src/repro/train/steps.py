"""Step builders: loss, train_step (grad-accum, clip, AdamW), serve steps.

These are the functions the launcher jits with explicit in/out shardings
(repro.launch.dryrun / repro.launch.train).  They are mesh-agnostic: all
distribution comes from shardings + the activation-sharding context.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ArchConfig
from repro.models import common
from repro.models.registry import get_model
from repro.optim import adamw


class TrainState(NamedTuple):
    params: Dict
    opt: adamw.OptState
    step: jax.Array


def init_state(cfg: ArchConfig, key) -> TrainState:
    api = get_model(cfg)
    params = api.init(cfg, key)
    return TrainState(params=params, opt=adamw.init(params),
                      step=jnp.zeros((), jnp.int32))


def loss_fn(cfg: ArchConfig, params, batch) -> Tuple[jax.Array, Dict]:
    api = get_model(cfg)
    labels = batch["labels"]
    mask = batch["mask"]
    if cfg.encoder_only:
        logits, aux = api.forward_train(cfg, params, embeds=batch["embeds"])
    elif cfg.vlm is not None:
        logits, aux = api.forward_train(
            cfg, params, tokens=batch["tokens"], patches=batch["patches"]
        )
        logits = logits[:, cfg.vlm.n_patches :]
    else:
        logits, aux = api.forward_train(cfg, params, tokens=batch["tokens"])
    loss, metrics = common.cross_entropy(logits, labels, mask)
    total = loss + aux.get("aux_loss", 0.0)
    metrics = dict(metrics, loss=loss, **{
        k: v for k, v in aux.items() if k != "aux_loss"})
    return total, metrics


def make_train_step(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig):
    """Returns train_step(state, batch) -> (state, metrics).

    cfg.accum_steps > 1 runs gradient accumulation over microbatches (the
    global batch is split on its leading dim inside the step), bounding
    activation memory at 76B scale.
    """

    def grads_of(params, batch):
        (_, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch), has_aux=True
        )(params)
        return grads, metrics

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        A = cfg.accum_steps
        if A > 1:
            def split(x):
                return x.reshape((A, x.shape[0] // A) + x.shape[1:])

            micro = jax.tree.map(split, batch)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )

            def acc2(carry, mb):
                (mets, g0) = carry
                (l, metrics), g = jax.value_and_grad(
                    lambda p: loss_fn(cfg, p, mb), has_aux=True
                )(state.params)
                g0 = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / A, g0, g
                )
                mets = jax.tree.map(lambda a, b: a + b / A, mets, metrics)
                return (mets, g0), ()

            met0 = jax.eval_shape(
                lambda p: loss_fn(cfg, p, jax.tree.map(lambda x: x[0], micro))[1],
                state.params,
            )
            met0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), met0)
            (metrics, grads), _ = lax.scan(acc2, (met0, zero), micro)
        else:
            grads, metrics = grads_of(state.params, batch)

        params, opt, opt_metrics = adamw.update(
            opt_cfg, state.params, grads, state.opt
        )
        metrics = dict(metrics, **opt_metrics)
        return TrainState(params, opt, state.step + 1), metrics

    return train_step


def make_prefill_step(cfg: ArchConfig):
    """Inference prefill: logits for a full prompt batch (no labels)."""
    api = get_model(cfg)

    def prefill_step(params, batch) -> jax.Array:
        if cfg.encoder_only:
            logits, _ = api.forward_train(cfg, params, embeds=batch["embeds"])
        elif cfg.vlm is not None:
            logits, _ = api.forward_train(
                cfg, params, tokens=batch["tokens"], patches=batch["patches"]
            )
        else:
            logits, _ = api.forward_train(cfg, params, tokens=batch["tokens"])
        return logits

    return prefill_step


def make_serve_step(cfg: ArchConfig):
    """One decode step: greedy next token + updated cache."""
    api = get_model(cfg)
    assert api.decode_step is not None, f"{cfg.name} has no decode step"

    def serve_step(params, batch):
        logits, cache = api.decode_step(
            cfg, params, batch["tokens"], batch["cache"], batch["lengths"]
        )
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return {
            "tokens": next_tok,
            "lengths": batch["lengths"] + 1,
            "cache": cache,
            "logits": logits,
        }

    return serve_step
