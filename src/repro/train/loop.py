"""Training loop: deterministic data, checkpointing, straggler monitor."""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional

import jax
import numpy as np

from repro.config import ArchConfig
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import make_batch
from repro.distributed.fault import StragglerMonitor
from repro.optim import adamw
from repro.train import steps


@dataclasses.dataclass
class TrainLoopConfig:
    batch_size: int = 8
    seq_len: int = 128
    total_steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    seed: int = 0


def train(
    cfg: ArchConfig,
    loop: TrainLoopConfig,
    opt_cfg: Optional[adamw.AdamWConfig] = None,
    state: Optional[steps.TrainState] = None,
    log_fn: Callable[[str], None] = print,
) -> Dict:
    opt_cfg = opt_cfg or adamw.AdamWConfig(
        warmup_steps=max(10, loop.total_steps // 20),
        total_steps=loop.total_steps,
    )
    step_fn = jax.jit(steps.make_train_step(cfg, opt_cfg), donate_argnums=(0,))

    start_step = 0
    ckpt = CheckpointManager(loop.ckpt_dir) if loop.ckpt_dir else None
    if state is None:
        if ckpt is not None and ckpt.latest_step() is not None:
            like = jax.eval_shape(
                lambda: steps.init_state(cfg, jax.random.key(loop.seed)))
            state, start_step = ckpt.restore(like)
            log_fn(f"restored checkpoint at step {start_step}")
        else:
            state = steps.init_state(cfg, jax.random.key(loop.seed))

    monitor = StragglerMonitor()
    losses = []
    t_start = time.time()
    for step in range(start_step, loop.total_steps):
        batch = make_batch(cfg, loop.batch_size, loop.seq_len, step, loop.seed)
        with monitor.timed(step):
            state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if (step + 1) % loop.log_every == 0:
            log_fn(
                f"step {step+1:5d} loss {loss:.4f} "
                f"acc {float(metrics['acc']):.3f} "
                f"gnorm {float(metrics['grad_norm']):.2f} "
                f"lr {float(metrics['lr']):.2e}"
            )
        if ckpt is not None and (step + 1) % loop.ckpt_every == 0:
            ckpt.save(state, step + 1)
    if ckpt is not None:
        ckpt.save(state, loop.total_steps)
        ckpt.wait()
    wall = time.time() - t_start
    return {
        "state": state,
        "losses": losses,
        "wall_s": wall,
        "straggler_events": monitor.events,
        "steps_per_s": (loop.total_steps - start_step) / max(wall, 1e-9),
    }
