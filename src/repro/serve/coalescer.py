"""Async pipelined admission: coalesce client requests into batched plans.

The paper's wait-free claim is about progress under heavy concurrent
traffic; this layer is where that traffic actually lands.  Many small
client requests (each a few CRUD/range ops) are admitted into a FIFO
queue, coalesced into power-of-two-bucketed `OpBatch` plans, and executed
with the host and the device overlapped:

  * plan N is dispatched with ``Uruv.apply_nowait`` — no host sync; the
    client adopts the speculative store immediately;
  * while the device executes N, the host drains the queue and builds and
    routes plan N+1 (numpy work, future bookkeeping) and dispatches it
    behind N — two plans in flight;
  * only when the pipeline is full (or a client blocks on its future) is
    the OLDEST plan settled: ``Uruv.confirm`` blocks on the accept flag
    (the deferred ``jax.block_until_ready``), materialises per-client
    results, and resolves futures.  A rejected plan (capacity / leaf-batch
    overflow — atomic, store untouched) rolls the client back and replays
    that plan and every later unconfirmed plan through the synchronous
    ``apply`` path at the exact same announce timestamps, so pipelining is
    invisible in results.

Each client gets an :class:`OpFuture` that slices its ops out of the
batched result: values, found mask, per-op linearization timestamps, and
complete range pages are bit-exact with issuing the same coalesced plans
synchronously (property-tested).

Coalescing is SKEW-AWARE (contention-adapting trees, arXiv:1709.00722):
zipfian hot-key traffic is exactly where a fixed batch width/deadline
falls over — wide batches concentrate same-leaf structural updates
(leaf-batch rejections -> slow-path rounds) and pile same-key versions
into deep chains.  The admission policy therefore (a) halves its target
width whenever a plan is rejected and doubles it back only while plans run
clean with a backlog, and (b) estimates skew per drained segment (the
duplicate-key fraction) — hot traffic halves the effective width and
shortens the deadline so hot keys drain in many small linearization
steps instead of one conflicted pass.

RANGE-bearing requests coalesce too, but their plans execute through the
synchronous ``apply`` (their pagination loop is host-driven); the
coalescer drains the pipeline first so linearization order stays FIFO.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.analysis.marks import device_pass
from repro.api import OP_NOP, OpBatch, Result, Uruv


@dataclasses.dataclass(frozen=True)
class AdmissionPolicy:
    """Coalescing knobs (DESIGN.md Sec 12).

    ``start_width``/``min_width``/``max_width`` bound the adaptive target
    plan width (always a power of two — plans NOP-pad to ``pow2_width``,
    so jit shape buckets stay O(log max_width)).  ``base_deadline_s`` is
    how long the oldest queued request may wait for the batch to fill
    before dispatching a partial plan; under hot traffic (duplicate-key
    fraction of a drained segment > ``hot_dup_frac``) the deadline
    contracts by ``hot_deadline_scale`` and the effective width halves.
    ``inflight_depth`` is the number of unconfirmed plans kept in flight
    (2 = host builds N+1 while the device executes N).
    """

    start_width: int = 64
    min_width: int = 8
    max_width: int = 1024
    base_deadline_s: float = 2e-3
    hot_dup_frac: float = 0.5
    hot_deadline_scale: float = 0.25
    inflight_depth: int = 2


class OpFuture:
    """One client request's slice of a batched result.

    ``result()`` drives the coalescer until this request's plan has been
    dispatched and settled, then returns the per-request :class:`Result`
    (values / found / per-op timestamps / complete range pages, announce
    positions rebased to the request).  ``submit_t``/``done_t`` (host
    monotonic clock) bracket queueing + batching + execution — the
    tail-latency harness reads per-op latency off them.
    """

    __slots__ = ("_coalescer", "n_ops", "submit_t", "done_t", "_result")

    def __init__(self, coalescer: "Coalescer", n_ops: int):
        self._coalescer = coalescer
        self.n_ops = n_ops
        self.submit_t = time.monotonic()
        self.done_t: Optional[float] = None
        self._result: Optional[Result] = None

    @property
    def done(self) -> bool:
        return self._result is not None

    def result(self) -> Result:
        while self._result is None:
            if not self._coalescer.pump(force=True):
                raise RuntimeError(
                    "coalescer made no progress with futures outstanding")
        return self._result

    def _resolve(self, result: Result) -> None:
        self._result = result
        self.done_t = time.monotonic()


@dataclasses.dataclass
class _Queued:
    future: OpFuture
    plan: OpBatch          # host arrays, builder-validated
    has_range: bool


@dataclasses.dataclass
class _InFlight:
    pending: object        # api.PendingPlan
    spans: List[Tuple[OpFuture, int, int]]


class Coalescer:
    """The admission pipeline over one `Uruv` client (module docstring).

    ``exclusive=True`` additionally donates the store pools into each pass
    (`donate_store`): only for a coalescer that exclusively owns its
    client's store buffers, and it caps the pipeline at ONE unconfirmed
    plan (a second speculative pass would consume the rollback buffers a
    rejected pass passes through) — host-build/device-execute overlap
    remains.  Sharded clients (no ``apply_nowait``) degrade to coalesced
    synchronous plans; everything else is unchanged.
    """

    def __init__(self, db: Uruv, policy: AdmissionPolicy = AdmissionPolicy(),
                 *, exclusive: bool = False, record: bool = False):
        self.db = db
        self.policy = policy
        self.exclusive = exclusive
        self.queue: Deque[_Queued] = collections.deque()
        self.inflight: Deque[_InFlight] = collections.deque()
        self.target_width = policy.start_width
        self.dispatch_log: Optional[List[Tuple[OpBatch, List[Tuple[OpFuture, int, int]]]]] = \
            [] if record else None
        self._last_dup = 0.0
        self._queued_ops = 0
        # executors without async dispatch (sharded) raise
        # NotImplementedError on first use; we then degrade to coalesced
        # synchronous plans for the life of the coalescer
        self._pipelined = True
        self._depth = max(1, 1 if exclusive else policy.inflight_depth)
        self.stats: Dict[str, int] = {
            "requests": 0, "ops": 0, "plans": 0, "plans_sync": 0,
            "plans_rejected": 0, "replays": 0, "padded_ops": 0,
            "max_queue_depth": 0, "hot_segments": 0,
        }

    # -------------------------------------------------------------- admission
    def submit(self, plan: OpBatch) -> OpFuture:
        """Admit one client request (an already-built `OpBatch`) and
        return its future.  Ops keep FIFO announce order across requests."""
        n = len(plan)
        if n == 0:
            raise ValueError("empty request")
        fut = OpFuture(self, n)
        has_range = bool(plan.range_positions.size)
        self.queue.append(_Queued(fut, plan, has_range))
        self._queued_ops += n
        self.stats["requests"] += 1
        self.stats["ops"] += n
        self.stats["max_queue_depth"] = max(self.stats["max_queue_depth"],
                                            len(self.queue))
        return fut

    # --------------------------------------------------------------- policy
    def _deadline_s(self) -> float:
        if self._last_dup > self.policy.hot_dup_frac:
            return self.policy.base_deadline_s * self.policy.hot_deadline_scale
        return self.policy.base_deadline_s

    def _effective_width(self) -> int:
        w = self.target_width
        if self._last_dup > self.policy.hot_dup_frac:
            w = max(self.policy.min_width, w // 2)
        return w

    def _adapt(self, rejected: bool) -> None:
        if rejected:
            self.target_width = max(self.policy.min_width,
                                    self.target_width // 2)
        elif (self._queued_ops >= self.target_width
              and self.target_width < self.policy.max_width):
            self.target_width *= 2

    def _note_skew(self, keys: np.ndarray, codes: np.ndarray) -> None:
        real = keys[codes != OP_NOP]
        if real.size:
            self._last_dup = 1.0 - len(np.unique(real)) / real.size
            if self._last_dup > self.policy.hot_dup_frac:
                self.stats["hot_segments"] += 1

    # ------------------------------------------------------------------ pump
    def pump(self, force: bool = False, now: Optional[float] = None) -> bool:
        """One admission step: build the next plan from the queue head
        (host work that overlaps the in-flight device pass), settle the
        oldest in-flight plan if the pipeline is full, dispatch.  Returns
        False when there was nothing to do (queue below width with an
        unexpired deadline and nothing to force)."""
        now = time.monotonic() if now is None else now
        width = self._effective_width()
        if self.queue and (
            force or self._queued_ops >= width
            or now - self.queue[0].future.submit_t >= self._deadline_s()
        ):
            reqs = self._take(width)
            self._dispatch(reqs)
            return True
        if force and self.inflight:
            self._retire_oldest()
            return True
        return False

    def flush(self) -> None:
        """Dispatch everything queued and settle every in-flight plan.

        For a durable client this also closes the group-commit window:
        with ``group_commit > 1`` up to that many confirmed plans may be
        awaiting one shared fsync (the bounded relaxation of the
        confirm-after-fsync contract, DESIGN.md Sec 14) — after flush
        every released result is on disk."""
        while self.queue or self.inflight:
            self.pump(force=True)
        self.db.sync_durable()
        self.db.lifecycle_tick()

    def _take(self, width: int) -> List[_Queued]:
        take = [self.queue.popleft()]
        total = take[0].future.n_ops
        while self.queue and total + self.queue[0].future.n_ops <= width:
            q = self.queue.popleft()
            take.append(q)
            total += q.future.n_ops
        self._queued_ops -= total
        return take

    # -------------------------------------------------------------- dispatch
    @device_pass(static=("reqs",))  # reqs is host metadata (futures + spans)
    def _dispatch(self, reqs: List[_Queued]) -> None:
        spans: List[Tuple[OpFuture, int, int]] = []
        at = 0
        for q in reqs:
            spans.append((q.future, at, at + q.future.n_ops))
            at += q.future.n_ops
        plan = OpBatch.concat(*[q.plan for q in reqs]).pad_to_pow2()
        self.stats["plans"] += 1
        self.stats["padded_ops"] += len(plan) - at
        # plan arrays are host numpy (built by OpBatch on the host);
        # probing them costs no device sync
        self._note_skew(np.asarray(plan.keys), np.asarray(plan.codes))  # uruvlint: disable=device-pass-purity
        if self.dispatch_log is not None:
            self.dispatch_log.append((plan, spans))
        if not (any(q.has_range for q in reqs) or not self._pipelined):
            while len(self.inflight) >= self._depth:
                self._retire_oldest()
            try:
                pending = self.db.apply_nowait(
                    plan, donate_store=self.exclusive)
            except NotImplementedError:
                self._pipelined = False
            else:
                self.inflight.append(_InFlight(pending, spans))
                return
        # host-driven pagination (RANGE) or a sync-only executor: drain
        # the pipeline (FIFO order), then one coalesced synchronous plan
        while self.inflight:
            self._retire_oldest()
        self.stats["plans_sync"] += 1
        self._materialize(spans, self.db.apply(plan))
        self._adapt(rejected=False)

    def _retire_oldest(self) -> None:
        entry = self.inflight.popleft()
        res = self.db.confirm(entry.pending)
        if res is not None:
            self._materialize(entry.spans, res)
            self._adapt(rejected=False)
            return
        # atomic rejection: the client rolled back to the pre-plan store;
        # every unconfirmed plan behind it ran on speculative state and is
        # invalid too — replay all of them synchronously, in order, at the
        # timestamps the restored clock re-derives (bit-exact)
        self.stats["plans_rejected"] += 1
        self._adapt(rejected=True)
        replay = [entry] + list(self.inflight)
        self.inflight.clear()
        for e in replay:
            self.stats["replays"] += 1
            self._materialize(e.spans, self.db.apply(e.pending.batch))

    # ------------------------------------------------------------- futures
    def _materialize(self, spans, res: Result) -> None:
        """Slice the batched Result into per-request Results and resolve
        the futures.  Every field keeps the batch's values verbatim —
        only announce positions (range_index) rebase to the request."""
        values = np.asarray(res.values)
        found = np.asarray(res.found)
        ts = np.asarray(res.timestamps)
        rng_pos = np.asarray(res.range_index).tolist()
        rng_resume = np.asarray(res.range_resume)
        for fut, a, b in spans:
            idx, pages, resumes = [], [], []
            for j, pos in enumerate(rng_pos):
                if a <= pos < b:
                    idx.append(pos - a)
                    pages.append(res.range_pages[j])
                    resumes.append(int(rng_resume[j]))
            fut._resolve(Result(
                values=values[a:b],
                found=found[a:b],
                timestamps=ts[a:b],
                range_index=np.asarray(idx, np.int32),
                range_pages=tuple(pages),
                range_resume=np.asarray(resumes, np.int32),
            ))
