"""Continuous-batching serving engine with an Uruv page/prefix table.

TPU-native serving keeps *slot-based contiguous* KV caches (paged gather is
a GPU idiom; TPU engines — JetStream-style — use fixed decode slots and
stream the cache; DESIGN.md Sec 2).  The paper's store provides the two
shared indexes a real engine needs, concurrently and linearizably:

  * prefix cache — key = rolling hash of a prompt prefix; value packs
    (slot, length).  Admission SEARCHes the longest cached prefix and
    copies the donor slot's KV; completed prompts INSERT their prefixes.
    Version timestamps give LRU eviction for free (oldest-ts versions).
  * sequence table — key = request id; value = slot; the scheduler's
    SNAPSHOT + RANGEQUERY sees a consistent view of in-flight sequences
    while admissions/completions keep mutating (the wait-free claim).

Decode is one jitted step over all slots; finished/empty slots are masked
by length.  Works with any arch exposing decode_step; transformer-family
archs also get one-shot prefill.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.api import KEY_DOMAIN_HI, KEY_MAX, OpBatch, Uruv, UruvConfig
from repro.config import ArchConfig
from repro.models import transformer
from repro.models.registry import get_model
from repro.serve.coalescer import AdmissionPolicy, Coalescer


def prefix_hash(tokens) -> int:
    """FNV-style rolling hash of a token prefix, clamped into the store's
    key domain ``[1, KEY_DOMAIN_HI - 1]``.

    The former ``& 0x7FFFFFFF`` mask could emit KEY_MAX (the padding
    sentinel) and KEY_MAX - 1 (the kernels' internal pad value): the
    store accepts an INSERT at either key and then ``lookup`` never
    finds it — the prefix entry is silently lost and that prefix is
    never reused (and the front-door guards now reject it loudly).  The
    modulus keeps every hash a valid, findable key.
    """
    h = 2166136261
    for t in tokens:
        h = (h * 16777619 + int(t) + 1) & KEY_MAX
    return int(h) % (KEY_DOMAIN_HI - 1) + 1


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int = 16
    eos: int = -1
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    prefix_reused: int = 0


class Engine:
    def __init__(self, cfg: ArchConfig, params, *, n_slots: int = 4,
                 max_len: int = 128):
        self.cfg = cfg
        self.params = params
        self.api = get_model(cfg)
        assert self.api.decode_step is not None
        self.n_slots = n_slots
        self.max_len = max_len
        self.cache = self.api.init_cache(cfg, n_slots, max_len)
        self.lengths = np.zeros(n_slots, np.int32)
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        # deque: admission pops from the head; a list's pop(0) is O(n)
        # per admit — quadratic drain on a deep backlog (the tail-latency
        # harness runs 10k-deep bursts through here)
        self.queue: Deque[Request] = collections.deque()
        # The table starts SMALL and self-sizes: admission churn retires
        # prefix entries (tombstones + split-leavings) continuously, and
        # the client's lifecycle policy grows pools on pressure and
        # interleaves incremental maintain passes — the engine runs
        # indefinitely with no CapacityError and no stop-the-world
        # compaction pauses on the admission path (DESIGN.md Sec 10).
        self.table = Uruv(UruvConfig(
            leaf_cap=16, max_leaves=256, max_versions=1 << 12))
        # table traffic goes through the pipelined admission layer: plans
        # coalesce into pow2 buckets and dispatch without a host sync
        # (DESIGN.md Sec 12); the engine blocks on a plan's future only
        # when it needs the donor answer
        self.coalescer = Coalescer(self.table, AdmissionPolicy())
        self._slot_keys: Dict[int, List[int]] = {i: [] for i in range(n_slots)}
        self._is_tf = cfg.family in ("dense", "moe", "vlm") and cfg.vlm is None

        self._decode = jax.jit(
            lambda p, t, c, l: self.api.decode_step(cfg, p, t, c, l)
        )
        if self._is_tf:
            self._prefill = jax.jit(
                lambda p, t: transformer.prefill(cfg, p, t, max_len),
                static_argnums=(),
            )

    # ------------------------------------------------------------- admission
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    @staticmethod
    def _select_donor(plens, vals) -> Tuple[int, int]:
        """Longest prefix whose cached entry covers it -> (donor_slot, plen).

        A hit is usable iff the published length covers the probed prefix
        (``ln >= plen`` — hash-collision guard); the donor slot's KV stays
        valid until the slot is re-admitted, which tombstones its keys.
        """
        best = (-1, 0)
        for plen, v in zip(plens, vals):
            if v >= 0:
                slot, ln = int(v) >> 16, int(v) & 0xFFFF
                if ln >= plen:
                    best = (slot, plen)
        return best

    def _lookup_prefix(self, prompt: List[int]) -> Tuple[int, int]:
        """Longest cached prefix -> (donor_slot, plen); (-1, 0) if none."""
        keys, plens = [], []
        for plen in range(1, len(prompt) + 1):
            keys.append(prefix_hash(prompt[:plen]))
            plens.append(plen)
        vals = self.table.lookup(np.array(keys, np.int32), pad_to_pow2=True)
        return self._select_donor(plens, vals)

    def _admission_pass(self, slot: int, prompt: List[int]) -> Tuple[int, int]:
        """Retire + prefix-lookup + publish as ONE mixed device pass.

        Announce order: DELETE the retiring slot's stale prefix keys,
        SEARCH every prompt prefix (each at its per-op snapshot, so the
        searches see the retirements but not this prompt's own publishes),
        then INSERT the new prefix entries — a single `bulk_apply` call on
        the fast path (DESIGN.md Sec 3) instead of the former
        update/sync/lookup/sync/update sequence.  Returns (donor, plen).
        """
        old_keys = self._slot_keys[slot]
        n = len(prompt)
        pkeys = [prefix_hash(prompt[:p]) for p in range(1, n + 1)]
        plan = OpBatch.concat(
            OpBatch.deletes(np.array(old_keys, np.int32)),
            OpBatch.searches(np.array(pkeys, np.int32)),
            OpBatch.inserts(
                np.array(pkeys, np.int32),
                np.array([(slot << 16) | p for p in range(1, n + 1)],
                         np.int32),
            ),
        )
        # the coalescer pow2-buckets the plan (admission widths vary per
        # prompt) and pipelines the device pass; result() is the first
        # host sync — the donor answer gates the KV copy
        res = self.coalescer.submit(plan).result()
        self._slot_keys[slot] = list(pkeys)
        search_vals = res.values[len(old_keys):len(old_keys) + n]
        return self._select_donor(range(1, n + 1), search_vals)

    def _copy_kv(self, dst: int, src: int, upto: int) -> None:
        def cp(x):
            if x.ndim >= 4 and x.shape[1] == self.n_slots:  # [L,B,...,S,hd]
                return x.at[:, dst, ..., :upto, :].set(x[:, src, ..., :upto, :])
            return x
        self.cache = jax.tree.map(cp, self.cache)

    def _admit(self) -> None:
        for slot in range(self.n_slots):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            donor, plen = self._admission_pass(slot, req.prompt)
            if donor >= 0 and donor != slot and plen > 1 and self._is_tf:
                self._copy_kv(slot, donor, plen)
                start, base_len = plen, plen
                req.prefix_reused = plen
            else:
                start, base_len = 0, 0
            # feed remaining prompt tokens
            if self._is_tf and start == 0 and len(req.prompt) > 1:
                toks = jnp.asarray(
                    np.array(req.prompt, np.int32)[None, :])
                _, cache1 = self._prefill(self.params, toks)
                def put(c, c1):
                    if c.ndim >= 4 and c.shape[1] == self.n_slots:
                        return c.at[:, slot].set(c1[:, 0])
                    return c
                self.cache = jax.tree.map(put, self.cache, cache1)
                self.lengths[slot] = len(req.prompt)
            else:
                # step-by-step prompt feed (SSM families / partial reuse)
                self.lengths[slot] = base_len
                for t in req.prompt[start:]:
                    self._step_single(slot, t)
            self.slot_req[slot] = req

    def _step_single(self, slot: int, token: int) -> None:
        toks = np.zeros(self.n_slots, np.int32)
        toks[slot] = token
        logits, cache = self._decode(
            self.params, jnp.asarray(toks), self.cache,
            jnp.asarray(self.lengths))
        self.cache = cache
        self.lengths[slot] += 1
        self._last_logits = np.asarray(logits)

    # ----------------------------------------------------------------- steps
    def step(self) -> None:
        """One engine tick: admit, batched decode, completions."""
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return
        toks = np.zeros(self.n_slots, np.int32)
        for i in active:
            r = self.slot_req[i]
            toks[i] = (r.out[-1] if r.out else r.prompt[-1])
        logits, cache = self._decode(
            self.params, jnp.asarray(toks), self.cache,
            jnp.asarray(self.lengths))
        self.cache = cache
        nxt = np.asarray(jnp.argmax(logits, -1))
        for i in active:
            r = self.slot_req[i]
            self.lengths[i] += 1
            tok = int(nxt[i])
            r.out.append(tok)
            if (tok == r.eos or len(r.out) >= r.max_new
                    or self.lengths[i] >= self.max_len - 1):
                r.done = True
                self.slot_req[i] = None

    def run(self, requests: List[Request], max_ticks: int = 1000
            ) -> List[Request]:
        for r in requests:
            self.submit(r)
        done: List[Request] = []
        for _ in range(max_ticks):
            self.step()
            done = [r for r in requests if r.done]
            if len(done) == len(requests):
                break
        return requests

    @property
    def table_stats(self) -> Dict[str, int]:
        """Store-lifecycle observability for the serving dashboard:
        device passes, grows, maintain passes, leaves reclaimed."""
        return dict(self.table.stats)

    # scheduler view: consistent snapshot of in-flight work.  One
    # `bulk_range` device pass serves the whole table (in-pass pagination;
    # no host round-trip per page), at a registered snapshot so concurrent
    # admissions/completions never perturb the view.
    def snapshot_view(self) -> List[Tuple[int, int]]:
        return self.snapshot_views([(0, KEY_DOMAIN_HI)])[0]

    def snapshot_views(self, bounds: List[Tuple[int, int]]
                       ) -> List[List[Tuple[int, int]]]:
        """N schedulers' key-range views in ONE batched device pass.

        All intervals share a single registered snapshot, so every consumer
        sees the same consistent table state (the "millions of users"
        surface: one `bulk_range` call, Q = len(bounds)).  The client's
        snapshot context releases the registration even on CapacityError —
        a leaked one would pin min_active_ts and starve compact() forever.
        """
        with self.table.snapshot() as snap:
            return self.table.range_all(
                [lo for lo, _ in bounds], [hi for _, hi in bounds],
                snap, scan_leaves=32, max_rounds=8)
