"""MVCC snapshot checkpointing + elastic restore.

The paper's snapshot semantics applied to training state (DESIGN.md Sec 3):

  * SAVE    — take a snapshot ts from the checkpoint index (an UruvStore),
    write each leaf to disk, then INSERT (shard_key -> manifest_id) entries
    and publish a manifest.  Training continues during the file writes (the
    arrays are immutable jax buffers; functional updates never mutate them —
    the same freeze-for-free argument as the store itself).
  * RESTORE — read the latest *complete* manifest (crash-safe: manifests are
    published atomically after all shards land) and device_put each leaf
    with the shardings of the *current* mesh — elastic: a checkpoint saved
    on mesh A restores on mesh B.
  * GC      — superseded checkpoints are tombstoned in the index and files
    of unreferenced manifests removed, gated by the version tracker
    (a restore-in-progress registers a snapshot and blocks reclamation).
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import numpy as np
import jax

from repro.api import KEY_DOMAIN_HI, Uruv, UruvConfig


def _flatten(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        out.append((name, leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_write = async_write
        self.index = Uruv(
            UruvConfig(leaf_cap=16, max_leaves=512, max_versions=1 << 14)
        )
        self._pending: Optional[threading.Thread] = None
        self._load_existing()

    # ------------------------------------------------------------------ save
    def save(self, state, step: int,
             extra: Optional[Dict[str, Any]] = None) -> None:
        self.wait()                              # one in-flight snapshot
        host = jax.tree.map(np.asarray, jax.device_get(state))

        def write():
            man_dir = self.dir / f"step_{step:08d}"
            tmp = self.dir / f".tmp_step_{step:08d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir()
            manifest = {"step": step, "leaves": [], **(extra or {})}
            for name, leaf in _flatten(host):
                fn = name.replace("/", "__") + ".npy"
                np.save(tmp / fn, leaf)
                manifest["leaves"].append(
                    {"name": name, "file": fn,
                     "shape": list(np.shape(leaf)),
                     "dtype": str(np.asarray(leaf).dtype)}
                )
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if man_dir.exists():
                shutil.rmtree(man_dir)
            tmp.rename(man_dir)                   # atomic publish
            # index insert: key = step, value = 1 (manifest id)
            self.index.insert([step], [1])
            self._gc()

        if self.async_write:
            self._pending = threading.Thread(target=write, daemon=True)
            self._pending.start()
        else:
            write()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        self.wait()
        with self.index.snapshot() as snap:
            items = self.index.range(0, KEY_DOMAIN_HI, snap)
        steps = [k for k, v in items if v == 1]
        return max(steps) if steps else None

    def restore(self, like, step: Optional[int] = None,
                shardings=None):
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings`` (optional pytree) enables elastic
        restore onto a different mesh."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError("no complete checkpoint found")
        man_dir = self.dir / f"step_{step:08d}"
        manifest = json.loads((man_dir / "manifest.json").read_text())
        by_name = {l["name"]: l for l in manifest["leaves"]}

        names = [n for n, _ in _flatten(like)]
        leaves = []
        for name in names:
            rec = by_name[name]
            leaves.append(np.load(man_dir / rec["file"]))
        treedef = jax.tree_util.tree_structure(like)
        host_tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            host_tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), host_tree, shardings
            )
        else:
            host_tree = jax.tree.map(jax.device_put, host_tree)
        return host_tree, step

    # ------------------------------------------------- store-aware round-trip
    def save_store(self, store, step: int) -> None:
        """Checkpoint an UruvStore (local or stacked/sharded) with its LIVE
        capacities recorded in the manifest, so :meth:`restore_store`
        round-trips across lifecycle growth — a store that grew from 4K to
        64K leaves restores with exactly its grown shapes, no ``like``
        template required (DESIGN.md Sec 10)."""
        cfg = store.cfg
        shards = int(np.asarray(store.ts).shape[0]) \
            if np.asarray(store.ts).ndim else 0
        self.save(store, step, extra={
            "uruv_config": dataclasses.asdict(cfg),
            "uruv_shards": shards,
        })

    def restore_store(self, step: Optional[int] = None, shardings=None):
        """Rebuild the UruvStore saved by :meth:`save_store`: the manifest's
        recorded ``UruvConfig`` regenerates the exact (possibly grown)
        template, elastic across meshes via ``shardings`` as in
        :meth:`restore`.  Returns ``(store, step)``."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError("no complete checkpoint found")
        man_dir = self.dir / f"step_{step:08d}"
        manifest = json.loads((man_dir / "manifest.json").read_text())
        if "uruv_config" not in manifest:
            raise ValueError(
                f"checkpoint step {step} was not written by save_store"
            )
        cfg = UruvConfig(**manifest["uruv_config"])
        # shape-only template: a grown store can be huge, so never
        # materialize it on device just to recover names + treedef
        like = jax.eval_shape(lambda: Uruv(cfg).store)
        if manifest.get("uruv_shards"):
            n = int(manifest["uruv_shards"])
            like = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype),
                like,
            )
        return self.restore(like, step, shardings=shardings)

    # -------------------------------------------------------------------- gc
    def _gc(self) -> None:
        with self.index.snapshot() as snap:
            items = self.index.range(0, KEY_DOMAIN_HI, snap)
        steps = sorted(k for k, v in items if v == 1)
        drop = steps[: -self.keep] if self.keep else []
        if drop:
            self.index.delete(np.array(drop, np.int32))
            self.index.compact()
            for s in drop:
                d = self.dir / f"step_{s:08d}"
                if d.exists():
                    shutil.rmtree(d)

    def _load_existing(self) -> None:
        steps = []
        for d in self.dir.glob("step_*"):
            if (d / "manifest.json").exists():
                steps.append(int(d.name.split("_")[1]))
        if steps:
            arr = np.array(sorted(steps), np.int32)
            self.index.insert(arr, np.ones_like(arr))
