"""MVCC snapshot checkpointing + elastic restore + delta checkpoints.

The paper's snapshot semantics applied to training state (DESIGN.md Sec 3):

  * SAVE    — take a snapshot ts from the checkpoint index (an UruvStore),
    write each leaf to disk, then INSERT (shard_key -> manifest_id) entries
    and publish a manifest.  Training continues during the file writes (the
    arrays are immutable jax buffers; functional updates never mutate them —
    the same freeze-for-free argument as the store itself).
  * RESTORE — read the latest *complete* manifest (crash-safe: manifests are
    published atomically after all shards land) and device_put each leaf
    with the shardings of the *current* mesh — elastic: a checkpoint saved
    on mesh A restores on mesh B.
  * GC      — superseded checkpoints are tombstoned in the index and files
    of unreferenced manifests removed, gated by the version tracker
    (a restore-in-progress registers a snapshot and blocks reclamation).

Delta checkpoints (DESIGN.md Sec 14): a full ``save_store`` of a
self-sized store (64k+ leaves) is unusable as a durability cadence, so
``save_store_delta`` writes only what changed since the previous saved
state — per-array changed ROWS for the leaf/index pools (row diff against
the retained host copy of the last save) and the allocator TAIL for the
version pool (append-only between compactions; the
``repro.core.lifecycle.pool_watermarks`` fast path skips the diff
entirely).  A delta manifest records ``base_step``; restore walks the
chain back to the base full save and replays the deltas forward.  GC
never drops a base that a kept delta still references, and
``_load_existing`` registers only steps whose chain is complete (and
removes ``.tmp_step_*`` wreckage a crashed async writer left behind).
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import numpy as np
import jax

from repro.api import (
    KEY_DOMAIN_HI, PoolWatermarks, Uruv, UruvConfig,
    pool_watermarks, version_tail_start,
)
from repro.distributed.fault import crash_point


def _flatten(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        out.append((name, leaf))
    return out


# version-pool arrays whose slots below the allocator watermark are
# immutable between compactions (lifecycle.version_tail_start)
_VER_TAIL_ARRAYS = ("ver_value", "ver_ts", "ver_next")

# row-delta sparsity cutoff: past this changed-row fraction a full array
# write is smaller than idx + rows
_DELTA_FULL_FRAC = 0.5


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_write = async_write
        self.index = Uruv(
            UruvConfig(leaf_cap=16, max_leaves=512, max_versions=1 << 14)
        )
        self._pending: Optional[threading.Thread] = None
        # host copy + watermarks of the last save_store/save_store_delta —
        # the diff base for the next delta (process-local by design: a
        # fresh manager starts a fresh chain with a full save)
        self._delta_base: Optional[Tuple[int, Dict[str, np.ndarray]]] = None
        self._delta_marks: Optional[PoolWatermarks] = None
        self._load_existing()

    # ------------------------------------------------------------------ save
    def save(self, state, step: int,
             extra: Optional[Dict[str, Any]] = None) -> None:
        self.wait()                              # one in-flight snapshot
        host = jax.tree.map(np.asarray, jax.device_get(state))

        def write():
            manifest = {"step": step, "leaves": [], **(extra or {})}
            with self._publish(step) as tmp:
                for name, leaf in _flatten(host):
                    fn = name.replace("/", "__") + ".npy"
                    np.save(tmp / fn, leaf)
                    manifest["leaves"].append(
                        {"name": name, "file": fn, "mode": "full",
                         "shape": list(np.shape(leaf)),
                         "dtype": str(np.asarray(leaf).dtype)}
                    )
                (tmp / "manifest.json").write_text(json.dumps(manifest))

        self._run_write(write)

    def _publish(self, step: int):
        """tmp-write -> atomic-rename -> index-insert -> GC, with the
        battery's crash points on either side of the rename."""
        mgr = self

        class _Publish:
            def __enter__(self):
                self.man_dir = mgr.dir / f"step_{step:08d}"
                self.tmp = mgr.dir / f".tmp_step_{step:08d}"
                if self.tmp.exists():
                    shutil.rmtree(self.tmp)
                self.tmp.mkdir()
                return self.tmp

            def __exit__(self, exc_type, exc, tb):
                if exc_type is not None:
                    return False
                crash_point("ckpt.tmp_written")
                if self.man_dir.exists():
                    shutil.rmtree(self.man_dir)
                self.tmp.rename(self.man_dir)     # atomic publish
                crash_point("ckpt.renamed")
                # index insert: key = step, value = 1 (manifest id)
                mgr.index.insert([step], [1])
                mgr._gc()
                return False

        return _Publish()

    def _run_write(self, write) -> None:
        if self.async_write:
            self._pending = threading.Thread(target=write, daemon=True)
            self._pending.start()
        else:
            write()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        self.wait()
        with self.index.snapshot() as snap:
            items = self.index.range(0, KEY_DOMAIN_HI, snap)
        steps = [k for k, v in items if v == 1]
        return max(steps) if steps else None

    def _manifest(self, step: int) -> Dict[str, Any]:
        man_path = self.dir / f"step_{step:08d}" / "manifest.json"
        if not man_path.exists():
            raise FileNotFoundError(f"no complete checkpoint at step {step}")
        return json.loads(man_path.read_text())

    def _host_leaves(self, step: int) -> Dict[str, np.ndarray]:
        """Materialize the saved host arrays at ``step``, replaying the
        delta chain back to its base full save (DESIGN.md Sec 14)."""
        manifest = self._manifest(step)
        man_dir = self.dir / f"step_{step:08d}"
        if manifest.get("kind") != "delta":
            return {
                rec["name"]: np.load(man_dir / rec["file"])
                for rec in manifest["leaves"]
            }
        out = self._host_leaves(manifest["base_step"])
        for rec in manifest["leaves"]:
            name, mode = rec["name"], rec["mode"]
            if mode == "same":
                continue
            if mode == "full":
                out[name] = np.load(man_dir / rec["file"])
            elif mode == "rows":
                with np.load(man_dir / rec["file"]) as z:
                    idx, rows = z["idx"], z["rows"]
                arr = out[name].copy()
                arr[idx] = rows
                out[name] = arr
            else:
                raise ValueError(f"unknown delta mode {mode!r} for {name}")
            if list(out[name].shape) != rec["shape"]:
                raise ValueError(
                    f"delta chain shape mismatch for {name}: "
                    f"{list(out[name].shape)} != {rec['shape']}")
        return out

    def restore(self, like, step: Optional[int] = None,
                shardings=None):
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings`` (optional pytree) enables elastic
        restore onto a different mesh."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError("no complete checkpoint found")
        by_name = self._host_leaves(step)
        names = [n for n, _ in _flatten(like)]
        leaves = [by_name[name] for name in names]
        treedef = jax.tree_util.tree_structure(like)
        host_tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            host_tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), host_tree, shardings
            )
        else:
            host_tree = jax.tree.map(jax.device_put, host_tree)
        return host_tree, step

    # ------------------------------------------------- store-aware round-trip
    def _store_extra(self, store) -> Dict[str, Any]:
        cfg = store.cfg
        shards = int(np.asarray(store.ts).shape[0]) \
            if np.asarray(store.ts).ndim else 0
        return {
            "uruv_config": dataclasses.asdict(cfg),
            "uruv_shards": shards,
            "uruv_ts": int(np.asarray(store.ts).max()),
        }

    def save_store(self, store, step: int, *, compactions: int = 0) -> None:
        """Checkpoint an UruvStore (local or stacked/sharded) with its LIVE
        capacities recorded in the manifest, so :meth:`restore_store`
        round-trips across lifecycle growth — a store that grew from 4K to
        64K leaves restores with exactly its grown shapes, no ``like``
        template required (DESIGN.md Sec 10).  Also (re)bases the delta
        chain: the retained host copy is what the next
        :meth:`save_store_delta` diffs against."""
        host = jax.tree.map(np.asarray, jax.device_get(store))
        self._delta_base = (step, dict(_flatten(host)))
        self._delta_marks = pool_watermarks(
            store, compactions=compactions)
        self.save(host, step, extra=self._store_extra(store))

    def save_store_delta(self, store, step: int, *,
                         compactions: int = 0) -> Dict[str, int]:
        """Checkpoint only what changed since the last ``save_store`` /
        ``save_store_delta`` in this manager (DESIGN.md Sec 14).

        Per array: ``same`` (bit-identical — nothing written), ``rows``
        (changed rows scattered by index; the version pool skips the diff
        via the ``lifecycle.version_tail_start`` watermark), or ``full``
        (0-d scalars, shape changes after ``grow``, or diffs too dense
        for a sparse win).  Returns per-mode array counts (the bench
        reads write bytes off the published directory).  Requires a base:
        call :meth:`save_store` first."""
        if self._delta_base is None:
            raise ValueError(
                "save_store_delta requires a prior save_store in this "
                "manager (the delta chain needs a base full save)")
        self.wait()
        base_step, base = self._delta_base
        host = jax.tree.map(np.asarray, jax.device_get(store))
        flat = _flatten(host)
        tail_start = version_tail_start(
            self._delta_marks, store, compactions=compactions) \
            if self._delta_marks is not None else None
        n_vers = int(np.asarray(host.n_vers).max())

        entries: List[Tuple[Dict[str, Any], Optional[Any]]] = []
        counts = {"same": 0, "rows": 0, "full": 0}
        for name, leaf in flat:
            arr = np.asarray(leaf)
            rec = {"name": name, "shape": list(arr.shape),
                   "dtype": str(arr.dtype)}
            old = base.get(name)
            mode, payload = "full", arr
            if old is not None and old.shape == arr.shape:
                if arr.ndim and name in _VER_TAIL_ARRAYS \
                        and tail_start is not None:
                    # append-only pool: the delta IS the allocator tail
                    idx = np.arange(tail_start, n_vers, dtype=np.int64)
                    mode, payload = "rows", (idx, arr[tail_start:n_vers])
                elif arr.ndim == 0:
                    mode = "same" if old == arr else "full"
                    payload = None if mode == "same" else arr
                else:
                    diff = old != arr
                    changed = np.flatnonzero(
                        diff.reshape(arr.shape[0], -1).any(axis=1))
                    if changed.size == 0:
                        mode, payload = "same", None
                    elif changed.size <= _DELTA_FULL_FRAC * arr.shape[0]:
                        mode, payload = "rows", (changed, arr[changed])
            rec["mode"] = mode
            counts[mode] += 1
            entries.append((rec, payload))

        self._delta_base = (step, dict(flat))
        self._delta_marks = pool_watermarks(
            store, compactions=compactions)
        manifest = {"step": step, "kind": "delta", "base_step": base_step,
                    "leaves": [], **self._store_extra(store)}

        def write():
            with self._publish(step) as tmp:
                for rec, payload in entries:
                    if rec["mode"] == "full":
                        fn = rec["name"].replace("/", "__") + ".npy"
                        np.save(tmp / fn, payload)
                        rec["file"] = fn
                    elif rec["mode"] == "rows":
                        fn = rec["name"].replace("/", "__") + ".npz"
                        idx, rows = payload
                        np.savez(tmp / fn, idx=idx, rows=rows)
                        rec["file"] = fn
                    manifest["leaves"].append(rec)
                (tmp / "manifest.json").write_text(json.dumps(manifest))

        self._run_write(write)
        return counts

    def restore_store(self, step: Optional[int] = None, shardings=None):
        """Rebuild the UruvStore saved by :meth:`save_store` (or a
        :meth:`save_store_delta` chain): the manifest's recorded
        ``UruvConfig`` regenerates the exact (possibly grown) template,
        elastic across meshes via ``shardings`` as in :meth:`restore`.
        Returns ``(store, step)``."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError("no complete checkpoint found")
        manifest = self._manifest(step)
        if "uruv_config" not in manifest:
            raise ValueError(
                f"checkpoint step {step} was not written by save_store"
            )
        cfg = UruvConfig(**manifest["uruv_config"])
        # shape-only template: a grown store can be huge, so never
        # materialize it on device just to recover names + treedef
        like = jax.eval_shape(lambda: Uruv(cfg).store)
        if manifest.get("uruv_shards"):
            n = int(manifest["uruv_shards"])
            like = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype),
                like,
            )
        return self.restore(like, step, shardings=shardings)

    def store_ts(self, step: Optional[int] = None) -> int:
        """The clock recorded at a ``save_store*`` step (manifest field —
        no array loads); recovery prunes WAL segments below it."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError("no complete checkpoint found")
        return int(self._manifest(step)["uruv_ts"])

    # -------------------------------------------------------------------- gc
    def _chain(self, step: int) -> List[int]:
        """``step`` plus every base it transitively needs (innermost last);
        raises FileNotFoundError when a link is missing."""
        out = [step]
        manifest = self._manifest(step)
        while manifest.get("kind") == "delta":
            step = int(manifest["base_step"])
            out.append(step)
            manifest = self._manifest(step)
        return out

    def _gc(self) -> None:
        with self.index.snapshot() as snap:
            items = self.index.range(0, KEY_DOMAIN_HI, snap)
        steps = sorted(k for k, v in items if v == 1)
        kept = steps[-self.keep:] if self.keep else steps
        # a delta's base chain must outlive it — never drop a step a kept
        # delta still restores through
        required = set(kept)
        for s in kept:
            required.update(self._chain(s))
        drop = [s for s in steps if s not in required]
        if drop:
            self.index.delete(np.array(drop, np.int32))
            self.index.compact()
            for s in drop:
                d = self.dir / f"step_{s:08d}"
                if d.exists():
                    shutil.rmtree(d)

    def _load_existing(self) -> None:
        # a crashed async writer leaves .tmp_step_* behind; left in place
        # they leak forever (nothing ever rmtree's a tmp dir whose step is
        # never saved again) — scrub them before anything else
        for tmp in self.dir.glob(".tmp_step_*"):
            shutil.rmtree(tmp)
        steps = []
        for d in self.dir.glob("step_*"):
            if (d / "manifest.json").exists():
                steps.append(int(d.name.split("_")[1]))
        complete = []
        for s in sorted(steps):
            try:
                self._chain(s)              # every delta link must resolve
            except FileNotFoundError:
                continue
            complete.append(s)
        if complete:
            arr = np.array(complete, np.int32)
            self.index.insert(arr, np.ones_like(arr))
