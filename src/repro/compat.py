"""Version-compatibility shims for the installed jax.

Centralises the two API moves that differ across the jax versions this
repo runs on (container pins vs TPU-image nightlies):

  * ``jax.sharding.AxisType`` / ``jax.make_mesh(..., axis_types=...)`` —
    absent before jax 0.5; :func:`make_mesh` falls back to the plain call.
  * ``jax.shard_map`` — lives under ``jax.experimental.shard_map`` on
    older versions.

Import from here instead of feature-testing jax at each call site.
"""

from __future__ import annotations

import jax

try:  # jax >= 0.5
    from jax.sharding import AxisType

    HAS_AXIS_TYPES = True
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None
    HAS_AXIS_TYPES = False

if hasattr(jax, "shard_map"):  # pragma: no cover - depends on installed jax
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, **kwargs):
        # the experimental replication checker predates rules for several
        # primitives the store uses (while_loop); disable it by default.
        kwargs.setdefault("check_rep", False)
        return _shard_map(f, **kwargs)


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with Auto axis types when the API supports them."""
    if HAS_AXIS_TYPES:
        return jax.make_mesh(
            axis_shapes, axis_names,
            axis_types=(AxisType.Auto,) * len(axis_names),
        )
    return jax.make_mesh(axis_shapes, axis_names)
