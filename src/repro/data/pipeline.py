"""Data pipeline: synthetic corpus + packing + Uruv streaming sample store.

The sample store is the paper's streaming-analytics use case verbatim
(Sec 1: real-time ingestion + consistent scans): producers INSERT samples
as they arrive; epoch readers take a SNAPSHOT and RANGEQUERY shard ranges —
readers never block producers and always see a consistent epoch.

Determinism & fault tolerance: batches are a pure function of
(seed, step), so restart-after-crash resumes the stream exactly
(repro.checkpoint records the step).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np
import jax.numpy as jnp

from repro.api import KEY_DOMAIN_HI, OpBatch, Uruv, UruvConfig
from repro.config import ArchConfig


# ---------------------------------------------------------------------------
# synthetic corpus (a Zipfian Markov chain -> learnable structure)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SyntheticCorpus:
    vocab: int
    seed: int = 0
    order_mod: int = 97

    def tokens(self, n: int, stream_seed: int) -> np.ndarray:
        """Deterministic pseudo-corpus: t_{i+1} = f(t_i) + noise (learnable)."""
        rng = np.random.default_rng((self.seed, stream_seed))
        out = np.empty(n, np.int32)
        t = int(rng.integers(0, self.vocab))
        zipf_pool = (rng.zipf(1.5, size=4096) - 1) % self.vocab
        for i in range(n):
            out[i] = t
            if rng.random() < 0.75:
                t = (t * 31 + 17) % self.vocab          # deterministic bigram
            else:
                t = int(zipf_pool[int(rng.integers(0, 4096))])
        return out


def make_batch(
    cfg: ArchConfig, B: int, S: int, step: int, seed: int = 0
) -> Dict[str, jnp.ndarray]:
    """Pure function of (cfg, step): the batch for one train step."""
    corpus = SyntheticCorpus(cfg.vocab, seed)
    if cfg.encoder_only:
        rng = np.random.default_rng((seed, step, 1))
        emb = rng.standard_normal((B, S, cfg.d_model), np.float32) * 0.5
        labels = rng.integers(0, cfg.vocab, (B, S)).astype(np.int32)
        mask = rng.random((B, S)) < 0.08        # masked-prediction positions
        return {
            "embeds": jnp.asarray(emb, jnp.float32),
            "labels": jnp.asarray(labels),
            "mask": jnp.asarray(mask),
        }
    toks = corpus.tokens(B * (S + 1), stream_seed=step).reshape(B, S + 1)
    batch = {
        "tokens": jnp.asarray(toks[:, :-1]),
        "labels": jnp.asarray(toks[:, 1:]),
        "mask": jnp.ones((B, S), jnp.bool_),
    }
    if cfg.vlm is not None:
        rng = np.random.default_rng((seed, step, 2))
        batch["patches"] = jnp.asarray(
            rng.standard_normal(
                (B, cfg.vlm.n_patches, cfg.vlm.patch_dim)) * 0.5,
            jnp.float32,
        )
    return batch


# ---------------------------------------------------------------------------
# Uruv-backed streaming sample store
# ---------------------------------------------------------------------------

class StreamingSampleStore:
    """Samples keyed by monotonically increasing id, values = corpus offsets.

    * ``ingest(ids, offsets)``     — producer INSERTs (wait-free bulk pass)
    * ``epoch_view()``             — snapshot ts for a consistent epoch
    * ``read_shard(lo, hi, snap)`` — RANGEQUERY a shard of sample ids
    * ``read_shards(bounds, snap)``— ALL epoch readers' shard ranges in ONE
                                     batched `bulk_range` device pass
    * ``retire_below(id)``         — DELETE consumed samples (tombstones);
                                     physical reclaim via compact()
    """

    def __init__(self, cfg: Optional[UruvConfig] = None):
        self.client = Uruv(cfg or UruvConfig())

    @property
    def store(self):
        """The current store snapshot (immutable pytree; tests/inspection)."""
        return self.client.store

    def ingest(self, ids: np.ndarray, offsets: np.ndarray) -> None:
        self.client.apply(OpBatch.inserts(ids, offsets))

    def epoch_view(self) -> int:
        return self.client.acquire_snapshot()

    def release(self, snap: int) -> None:
        self.client.release_snapshot(snap)

    def read_shard(self, lo: int, hi: int, snap: int) -> List[Tuple[int, int]]:
        return self.read_shards([(lo, hi)], snap)[0]

    def read_shards(
        self, bounds: List[Tuple[int, int]], snap: int
    ) -> List[List[Tuple[int, int]]]:
        """Epoch fan-out: Q shard ranges answered in ONE device pass.

        Every reader's [lo, hi] interval resolves at the same registered
        snapshot, so all shards observe one consistent epoch regardless of
        concurrent ingest (the paper's streaming-analytics scan, batched
        across consumers instead of loop-per-consumer)."""
        return self.client.range_all(
            [lo for lo, _ in bounds], [hi for _, hi in bounds],
            snap, scan_leaves=32, max_rounds=8,
        )

    def retire_below(self, sample_id: int, batch_width: int = 256) -> None:
        with self.client.snapshot() as snap:
            items = self.read_shard(0, sample_id - 1, snap)
        ids = np.array([k for k, _ in items], np.int32)
        for i in range(0, len(ids), batch_width):
            self.client.apply(OpBatch.deletes(ids[i : i + batch_width]))

    def compact(self) -> int:
        return self.client.compact()

    def live_count(self) -> int:
        with self.client.snapshot() as snap:
            return len(self.read_shard(0, KEY_DOMAIN_HI, snap))


def epoch_iterator(
    store: StreamingSampleStore,
    corpus: SyntheticCorpus,
    cfg: ArchConfig,
    B: int,
    S: int,
    n_shards: int = 1,
    shard: int = 0,
) -> Iterator[Dict[str, jnp.ndarray]]:
    """Consume a consistent epoch of the sample store shard-by-shard."""
    snap = store.epoch_view()
    try:
        items = store.read_shard(0, KEY_DOMAIN_HI, snap)
        mine = [off for sid, off in items if sid % n_shards == shard]
        for i in range(0, len(mine) - B + 1, B):
            offs = mine[i : i + B]
            toks = np.stack(
                [corpus.tokens(S + 1, stream_seed=o) for o in offs]
            )
            yield {
                "tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:]),
                "mask": jnp.ones((B, S), jnp.bool_),
            }
    finally:
        store.release(snap)
