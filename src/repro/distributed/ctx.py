"""Activation-sharding context.

Model code calls ``shard_act(x, kind)`` at a few key points (residual
stream, logits, KV cache).  Outside a mesh context these are no-ops, so
smoke tests and single-device runs never touch jax device state.  The step
builders (repro.train.steps / repro.launch.dryrun) install the context.

Kinds (axes refer to the production mesh of DESIGN.md Sec 5):
  residual  [B, S, D]      B -> (pod, data);  S -> model if sequence_parallel
  tokens    [B, S]         B -> (pod, data)
  logits    [B, S, V]      B -> (pod, data);  V -> model
  kv_cache  [L, B, KVH, S, D]   B -> (pod, data);  S -> model
  seq_shard [..., S, ...]  long-context decode: S over every mesh axis
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CTX: contextvars.ContextVar[Optional["ShardCtx"]] = contextvars.ContextVar(
    "repro_shard_ctx", default=None
)


class ShardCtx:
    def __init__(self, mesh: Mesh, *, sequence_parallel: bool = True,
                 long_context: bool = False):
        self.mesh = mesh
        self.sequence_parallel = sequence_parallel
        self.long_context = long_context
        names = mesh.axis_names
        self.batch_axes: Tuple[str, ...] = tuple(
            a for a in ("pod", "data") if a in names
        )
        self.model_axis: Optional[str] = "model" if "model" in names else None

    def spec(self, kind: str, ndim: int) -> Optional[P]:
        ba = self.batch_axes
        # canonical PartitionSpec entries: bare axis name unless compound
        b = (ba[0] if len(ba) == 1 else ba) if ba else None
        m = self.model_axis
        sp = m if self.sequence_parallel else None
        if kind == "residual":
            return P(b, sp, None)
        if kind == "tokens":
            return P(b, None)
        if kind == "logits":
            return P(b, None, m)
        if kind == "kv_cache":
            return P(None, b, None, m, None)
        if kind == "ssm_state":  # [L, B, heads, ...]
            return P(None, b, m, *([None] * (ndim - 3)))
        if kind == "moe_tokens":      # [T, D] flattened tokens pre-dispatch
            return P(b, None)
        if kind == "moe_experts":     # [E, C, D] dispatched expert blocks
            return P(m, b, None)      # EP over model, capacity over data
        if kind == "moe_weight":      # [E, D, F] gather-on-use (ZeRO): drop
            return P(m, None, None)   # the FSDP axis inside the layer
        if kind == "kv4":
            # per-layer decode cache [B, KVH, S, hd] — MUST match the
            # cache's resident sharding (batch over data, seq over model;
            # long-context: seq over every axis). A conflicting constraint
            # here re-gathers the whole cache per layer (EXPERIMENTS.md
            # §Perf iteration 1).
            if self.long_context:
                all_axes = ba + ((m,) if m else ())
                return P(None, None, all_axes if all_axes else None, None)
            return P(b, None, m, None)
        if kind == "seq_shard":
            # batch=1 long-context: sequence over the whole mesh
            all_axes = ba + ((m,) if m else ())
            spec = [None] * ndim
            spec[-2] = all_axes if all_axes else None
            return P(*spec)
        return None


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], *, sequence_parallel: bool = True,
             long_context: bool = False):
    tok = _CTX.set(
        ShardCtx(mesh, sequence_parallel=sequence_parallel,
                 long_context=long_context)
        if mesh else None
    )
    try:
        yield
    finally:
        _CTX.reset(tok)


def _guard(spec: P, shape, mesh: Mesh) -> P:
    """Drop axes that do not divide the corresponding dim (replicate)."""
    out = []
    for d, entry in enumerate(spec):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        out.append(entry if shape[d] % n == 0 else None)
    return P(*out)


def shard_act(x: jax.Array, kind: str) -> jax.Array:
    ctx = _CTX.get()
    if ctx is None:
        return x
    spec = ctx.spec(kind, x.ndim)
    if spec is None:
        return x
    spec = _guard(spec, x.shape, ctx.mesh)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec)
    )
