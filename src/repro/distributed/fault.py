"""Fault tolerance: straggler monitoring, crash/restart, elastic resharding.

At 1000+ nodes the failure model is: slow hosts (stragglers), dead hosts
(crash -> restart from snapshot checkpoint), and resizes (elastic).  The
pieces here are host-level and deterministic, hence testable on CPU:

  * StragglerMonitor — EWMA + MAD step-time detector; pluggable actions
    (shrink microbatch, flag host, trigger checkpoint).
  * run_with_restarts — crash-simulating train-loop driver used by tests:
    training is a pure function of (checkpoint, data stream step), so a
    restart reproduces the exact trajectory.
  * reshard — move a state pytree onto a new mesh (elastic scale up/down);
    combined with CheckpointManager.restore(shardings=...) this is the
    checkpoint -> resize -> resume path.
  * crash_point — the kill -9 fault-injection hook the durability battery
    drives (tests/test_wal_recovery.py): named points on the WAL append /
    fsync / checkpoint publish paths SIGKILL the process mid-operation
    when ``URUV_CRASH_POINT`` selects them, so recovery is exercised
    against genuinely torn on-disk state, not a polite exception.
"""

from __future__ import annotations

import dataclasses
import os
import signal
import statistics
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax

# ---------------------------------------------------------------------------
# kill -9 fault injection (the durability battery's crash driver)
# ---------------------------------------------------------------------------

CRASH_POINT_ENV = "URUV_CRASH_POINT"

# per-process hit counters, keyed by crash-point name — the env selector
# ``name:k`` crashes on the k-th time execution reaches ``name``
_crash_hits: Dict[str, int] = {}


def reset_crash_counters() -> None:
    """Forget per-process crash-point hit counts (test isolation)."""
    _crash_hits.clear()


def crash_point(name: str, flush: Optional[Callable[[], None]] = None) -> None:
    """Die by SIGKILL when the ``URUV_CRASH_POINT`` selector matches.

    The selector is ``<name>`` (crash on the first hit) or ``<name>:<k>``
    (crash on the k-th hit — randomized crash timing without randomizing
    the code path).  ``flush`` runs right before the kill so deliberately
    torn state (e.g. a half-written WAL record sitting in a userspace
    buffer) actually reaches the OS file — SIGKILL forfeits every Python
    buffer, which would otherwise make the torn-write points unreachable.

    A no-op (one dict lookup) when the env var is unset, so the hooks are
    safe to leave on production paths.
    """
    sel = os.environ.get(CRASH_POINT_ENV)
    if not sel:
        return
    want, _, k = sel.partition(":")
    if want != name:
        return
    hits = _crash_hits.get(name, 0) + 1
    _crash_hits[name] = hits
    if hits < (int(k) if k else 1):
        return
    if flush is not None:
        flush()
    os.kill(os.getpid(), signal.SIGKILL)


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration: float
    median: float
    factor: float


class StragglerMonitor:
    """Flags steps slower than ``factor`` x running median (+ MAD guard)."""

    def __init__(self, window: int = 32, factor: float = 2.5,
                 min_samples: int = 5):
        self.window = window
        self.factor = factor
        self.min_samples = min_samples
        self.durations: List[float] = []
        self.events: List[StragglerEvent] = []
        self.actions: List[Callable[[StragglerEvent], None]] = []

    def on_straggler(self, fn: Callable[[StragglerEvent], None]) -> None:
        self.actions.append(fn)

    def record(self, step: int, duration: float) -> Optional[StragglerEvent]:
        hist = self.durations[-self.window:]
        self.durations.append(duration)
        if len(hist) < self.min_samples:
            return None
        med = statistics.median(hist)
        mad = statistics.median([abs(x - med) for x in hist]) or 1e-9
        if duration > self.factor * med and duration > med + 6 * mad:
            ev = StragglerEvent(step, duration, med, duration / med)
            self.events.append(ev)
            for fn in self.actions:
                fn(ev)
            return ev
        return None

    def timed(self, step: int):
        mon = self

        class _Timer:
            def __enter__(self):
                self.t0 = time.perf_counter()
                return self

            def __exit__(self, *exc):
                mon.record(step, time.perf_counter() - self.t0)

        return _Timer()


def reshard(tree, shardings):
    """Elastic move of a pytree onto new shardings (new mesh ok)."""
    host = jax.device_get(tree)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), host, shardings)


def run_with_restarts(
    *,
    init_fn: Callable[[], Tuple],          # () -> state
    step_fn: Callable,                      # (state, batch) -> (state, metrics)
    batch_fn: Callable[[int], Dict],        # step -> batch (deterministic)
    ckpt,                                   # CheckpointManager
    total_steps: int,
    ckpt_every: int = 10,
    crash_at: Optional[List[int]] = None,   # simulated host deaths
):
    """Crash-tolerant training driver.

    On (simulated) crash: drop all live state, restore the latest complete
    checkpoint, resume the deterministic data stream at the restored step.
    Returns (final_state, per-step metrics including replays).
    """
    crash_at = sorted(crash_at or [])
    history = []
    state = None
    step = 0
    latest = ckpt.latest_step()
    if latest is not None:
        state, step = ckpt.restore(jax.eval_shape(init_fn))
    else:
        state = init_fn()
        ckpt.save(state, 0)

    while step < total_steps:
        if crash_at and step == crash_at[0]:
            crash_at.pop(0)
            ckpt.wait()
            state = None                     # simulate losing device state
            restored, rstep = ckpt.restore(jax.eval_shape(init_fn))
            history.append(("restart", step, rstep))
            state, step = restored, rstep
            continue
        batch = batch_fn(step)
        state, metrics = step_fn(state, batch)
        step += 1
        history.append(("step", step, float(metrics.get("loss", 0.0))))
        if step % ckpt_every == 0:
            ckpt.save(state, step)
    ckpt.wait()
    return state, history
