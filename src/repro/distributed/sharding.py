"""Sharding rules: param-name-pattern -> PartitionSpec, with guards.

DP over (pod, data); TP over model (Megatron column/row); EP for MoE experts
over model; FSDP (params + optimizer state over data) optional; SP for the
residual stream handled by repro.distributed.ctx.

Every rule is guarded by divisibility — a dim that does not divide by its
mesh axis falls back to replication, so all ten architectures lower on the
same mesh without per-arch special cases.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    fsdp: bool = True           # shard params/opt-state over data axis too
    sequence_parallel: bool = True


def _ax(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _fits(shape, dim, n) -> bool:
    return 0 <= dim < len(shape) and shape[dim] % n == 0 and shape[dim] >= n


def _batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def param_spec(
    path: str, shape: Tuple[int, ...], mesh: Mesh, policy: ShardingPolicy
) -> P:
    """Sharding for one parameter, identified by its tree path."""
    model_n = _ax(mesh, "model")
    data_axes = _batch_axes(mesh)
    data_n = 1
    for a in data_axes:
        data_n *= mesh.shape[a]
    nd = len(shape)
    spec = [None] * nd
    name = path.split("/")[-1]

    def set_model(dim: int) -> bool:
        d = dim % nd
        if model_n > 1 and _fits(shape, d, model_n) and spec[d] is None:
            spec[d] = "model"
            return True
        return False

    def set_fsdp(preferred: Tuple[int, ...]):
        if not policy.fsdp or data_n <= 1:
            return
        for dim in preferred:
            d = dim % nd
            if spec[d] is None and _fits(shape, d, data_n):
                spec[d] = data_axes if len(data_axes) > 1 else data_axes[0]
                return

    if name in ("embed",):                      # [V, D]
        set_model(-2)
        set_fsdp((-1,))
    elif name in ("unembed",):                  # [D, V]
        set_model(-1)
        set_fsdp((-2,))
    elif name in ("wq", "wk", "wv"):            # [*, D, H|KVH, hd]
        if not set_model(-2):                   # heads over model (TP)
            set_model(-3)                       # else contract dim
        set_fsdp((-3, -1))
    elif name == "wo":                          # [*, H, hd, D]
        set_model(-3)
        set_fsdp((-1,))
    elif name in ("w1", "w3", "up", "in_proj"):  # [*, (E,) D, F]
        if len(shape) >= 4 or "moe" in path:    # moe experts [*, E, D, F]
            set_model(-3)                       # EP: experts over model
            set_fsdp((-1,))
        else:
            set_model(-1)                       # column parallel
            set_fsdp((-2,))
    elif name in ("w2", "out_proj", "down", "out"):  # [*, (E,) F, D]
        if "moe" in path and len(shape) >= 4:
            set_model(-3)
            set_fsdp((-2,))
        else:
            set_model(-2)                       # row parallel
            set_fsdp((-1,))
    elif name == "conv_w":                      # [*, K, C]
        set_model(-1)
    elif name in ("W",):                        # slstm [*, d, nh, 4, hd]
        set_model(-1)
        set_fsdp((-4,))
    elif name in ("R",):                        # slstm [*, nh, hd, 4, hd]
        set_model(-1)
    elif name == "router":                      # [*, D, E]
        set_fsdp((-2,))
    # 1-D / small params (norm scales, biases, gates): replicate.
    return P(*spec)


def param_shardings(param_tree, mesh: Mesh, policy: ShardingPolicy):
    """Pytree of NamedShardings congruent with ``param_tree``."""

    def visit(path, leaf):
        pstr = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        return NamedSharding(
            mesh, param_spec(pstr, leaf.shape, mesh, policy)
        )

    return jax.tree_util.tree_map_with_path(visit, param_tree)


def batch_specs(batch_tree, mesh: Mesh, *, long_context: bool = False):
    """Shardings for step inputs (tokens/labels/patches/cache/...)."""
    b = _batch_axes(mesh)
    b = b if len(b) > 1 else (b[0] if b else None)
    model = "model" if "model" in mesh.axis_names else None

    def visit(path, leaf):
        names = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        top = names[0] if names else ""
        nd = len(leaf.shape)
        spec = [None] * nd
        if top in ("k", "v", "attn_k", "attn_v") or "cache" in names[:2]:
            # KV cache [L, B, KVH, S, hd] or ssm state [L, B, H, ...]
            if nd == 5 and leaf.shape[3] > 256:   # kv cache: seq dim big
                if long_context:
                    spec[3] = tuple(a for a in ("pod", "data", "model")
                                    if a in mesh.axis_names)
                else:
                    if leaf.shape[1] % _pn(mesh, b) == 0:
                        spec[1] = b
                    if model and leaf.shape[3] % mesh.shape["model"] == 0:
                        spec[3] = model
            else:
                if nd >= 2 and not long_context and leaf.shape[1] % _pn(mesh, b) == 0:
                    spec[1] = b
                if (nd >= 3 and model
                        and leaf.shape[2] % mesh.shape["model"] == 0):
                    spec[2] = model
        else:
            # plain batch-major arrays: tokens/labels/mask/embeds/patches
            if nd >= 1 and leaf.shape[0] % _pn(mesh, b) == 0:
                spec[0] = b
            if top == "embeds" and nd == 3 and model and (
                leaf.shape[1] % mesh.shape["model"] == 0):
                spec[1] = model   # SP on provided frame embeddings
        return P(*spec)

    return jax.tree_util.tree_map_with_path(visit, batch_tree)


def _pn(mesh: Mesh, b) -> int:
    if b is None:
        return 1
    if isinstance(b, str):
        return mesh.shape[b]
    n = 1
    for a in b:
        n *= mesh.shape[a]
    return n


def named(tree_specs, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )
