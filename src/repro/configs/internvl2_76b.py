"""internvl2-76b [vlm]: InternViT (stub frontend) + 80L d8192 64H GQA(8)
ff28672 V128256 LM backbone. [arXiv:2404.16821; unverified]"""
from repro.config import ArchConfig, VLMConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="internvl2-76b", family="vlm",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=28672, vocab=128256, head_dim=128,
        rope_theta=500000.0, tie_embeddings=False,
        vlm=VLMConfig(patch_dim=3200, n_patches=256),
        accum_steps=4,   # 76B activations need microbatching at train_4k
    )
