"""xlstm-350m [ssm]: 24 blocks d1024, 7:1 mLSTM:sLSTM groups, V50304,
d_ff=0 (in-block projections). [arXiv:2405.04517; unverified]"""
from repro.config import ArchConfig, XLSTMConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="xlstm-350m", family="ssm",
        n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab=50304,
        xlstm=XLSTMConfig(m_per_group=7, proj_factor=2.0, d_conv=4,
                          head_dim=256),
        tie_embeddings=True,
    )
