"""gemma3-1b [dense]: 26L d1152 4H GQA(1) ff6912 V262144; 5:1 local:global
sliding window (W=1024), gelu, qk-norm, 128k context.
[hf:google/gemma-3-1b-pt; unverified]"""
from repro.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="gemma3-1b", family="dense",
        n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1,
        d_ff=6912, vocab=262144, head_dim=256,
        rope_theta=1000000.0, qk_norm=True, act="gelu",
        window=1024, global_every=6, tie_embeddings=True,
    )
