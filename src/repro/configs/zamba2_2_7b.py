"""zamba2-2.7b [hybrid]: 54L d2560 Mamba2 backbone (state 64) + shared
attention block (32H/kv32) every 6 layers, ff10240 V32000.
[arXiv:2411.15242; hf]"""
from repro.config import ArchConfig, SSMConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="zamba2-2.7b", family="hybrid",
        n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
        d_ff=10240, vocab=32000, head_dim=80,
        ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=256),
        hybrid_attn_every=6, tie_embeddings=True,
        accum_steps=4,   # activation fit at train_4k (16 GiB HBM)
    )
