"""hubert-xlarge [audio]: 48L d1280 16H MHA ff5120, 504 cluster classes;
encoder-only, conv waveform frontend stubbed (frame embeddings provided).
[arXiv:2106.07447; unverified]"""
from repro.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="hubert-xlarge", family="audio",
        n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
        d_ff=5120, vocab=504, encoder_only=True,
        norm="rmsnorm", act="gelu", tie_embeddings=False,
        rope_theta=10000.0,
    )
