"""The paper's own workload configuration (benchmark Sec 6): store capacity
and batch geometry for the Fig. 8 / Fig. 9 reproductions."""
import dataclasses

from repro.api import UruvConfig


@dataclasses.dataclass(frozen=True)
class UruvWorkload:
    store: UruvConfig = UruvConfig(
        leaf_cap=64, max_leaves=1 << 14, max_versions=1 << 20, max_chain=64
    )
    key_universe: int = 500_000_000   # paper: keys drawn from [1, 500M]
    prefill: int = 1_000_000          # scaled from the paper's 100M (CPU-JAX)
    batch: int = 4096                 # announce-array width
    range_size: int = 1000            # paper: 1K range queries


def config() -> UruvWorkload:
    return UruvWorkload()
