"""olmoe-1b-7b [moe]: 16L d2048 16H MHA, 64 experts top-8 (d_expert 1024),
V50304 — 1B active / 7B total. [arXiv:2409.02060; hf]"""
from repro.config import ArchConfig, MoEConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="olmoe-1b-7b", family="moe",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1024, vocab=50304,
        moe=MoEConfig(n_experts=64, top_k=8, d_expert=1024),
        accum_steps=4,   # activation fit at train_4k (16 GiB HBM)
        rope_theta=10000.0, tie_embeddings=True,
    )
