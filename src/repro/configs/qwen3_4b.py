"""qwen3-4b [dense]: 36L d2560 32H GQA(8) ff9728 V151936; qk-norm.
[hf:Qwen/Qwen3-8B; hf]"""
from repro.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-4b", family="dense",
        n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8,
        d_ff=9728, vocab=151936, head_dim=128,
        rope_theta=1000000.0, qk_norm=True, tie_embeddings=True,
    )
