"""deepseek-moe-16b [moe]: 28L d2048 16H MHA, 2 shared + 64 routed top-6
fine-grained experts (d_expert 1408), V102400. [arXiv:2401.06066; hf]
(Simplification: the released model keeps layer 0 dense; we apply MoE
uniformly for scan-uniformity — FLOP delta < 2%. DESIGN.md Sec 6.)"""
from repro.config import ArchConfig, MoEConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-moe-16b", family="moe",
        n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1408, vocab=102400,
        moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared=2),
        accum_steps=4,   # activation fit at train_4k (16 GiB HBM)
        rope_theta=10000.0, tie_embeddings=True,
    )
