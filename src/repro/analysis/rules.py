"""The uruvlint rule catalog (DESIGN.md Sec 13).

Every headline structural claim the repo makes has a rule that proves it
statically, replacing the former ``grep -RnE`` gates in scripts/check.sh:

  layering-api        outside core/ only repro.api touches the mutable
                      internals (core.store / batch / sharded / lifecycle)
  layering-index      descent internals (dir_keys / dir_leaf /
                      searchsorted) confined to index / backend /
                      baseline / kernels-uruv_search
  device-pass-purity  no host syncs inside ``@device_pass`` hot paths
  donation-safety     no use of a store after it was donated into a
                      ``donate_argnums`` pass (the PR 7 rollback hazard)
  determinism         no wall clock / host RNG / set-iteration order in
                      the op_ts plumbing (bit-exact sharded == local)
  kernel-parity       each kernels/<k>/ package: kernel and ref twins
                      agree on signatures
  kernel-vmem         BlockSpec footprint of each pallas_call stays
                      under a VMEM budget (bounded block shapes only)
  sentinel-literal    key-sentinel literals (2**31-1 family) appear only
                      in the blessed domain module core/ref.py — the
                      exact silent-loss bug class fixed in PR 7
"""

from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.engine import (
    ERROR, WARNING, FileContext, Finding, Rule,
)
from repro.core.ref import KEY_MAX


def _const_eval(node: ast.AST) -> Optional[int]:
    """Fold an int-literal expression tree (``2**31 - 1``); None when any
    leaf is not a constant."""
    if isinstance(node, ast.Constant):
        return node.value if type(node.value) is int else None
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        v = _const_eval(node.operand)
        return None if v is None else -v
    if isinstance(node, ast.BinOp):
        a, b = _const_eval(node.left), _const_eval(node.right)
        if a is None or b is None:
            return None
        try:
            if isinstance(node.op, ast.Add):
                return a + b
            if isinstance(node.op, ast.Sub):
                return a - b
            if isinstance(node.op, ast.Mult):
                return a * b
            if isinstance(node.op, ast.Pow):
                return a ** b if abs(b) < 64 else None
            if isinstance(node.op, ast.LShift):
                return a << b if 0 <= b < 64 else None
            if isinstance(node.op, ast.BitOr):
                return a | b
            if isinstance(node.op, ast.BitAnd):
                return a & b
            if isinstance(node.op, ast.FloorDiv) and b:
                return a // b
            if isinstance(node.op, ast.Mod) and b:
                return a % b
        except Exception:
            return None
    return None


def _root_name(node: ast.AST) -> Optional[str]:
    """Leftmost Name of an attribute chain (``np.random.x`` -> ``np``)."""
    while isinstance(node, ast.Attribute):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def _expr_key(node: ast.AST) -> Optional[str]:
    """Stable key for a Name / dotted-Name chain (``self._store``)."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _expr_key(node.value)
        return None if base is None else f"{base}.{node.attr}"
    return None


# ---------------------------------------------------------------------------
# 1. layering-api — the former check.sh api grep gate, as import analysis
# ---------------------------------------------------------------------------

RESTRICTED_CORE = ("store", "batch", "sharded", "lifecycle")


class LayeringApiRule(Rule):
    id = "layering-api"
    description = (
        "outside repro/core, only repro/api may import the mutable core "
        "internals (core.store/batch/sharded/lifecycle); everything else "
        "goes through the repro.api front door (DESIGN.md Sec 9)")

    def check_file(self, ctx: FileContext) -> Iterable[Finding]:
        if ctx.in_dir("repro/core", "repro/api"):
            return
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    yield from self._check(ctx, node, alias.name)
            elif isinstance(node, ast.ImportFrom):
                mod = self._absolute(ctx, node)
                if mod is None:
                    continue
                if mod == "repro.core":
                    for alias in node.names:
                        if alias.name in RESTRICTED_CORE:
                            yield self._finding(
                                ctx, node, f"repro.core.{alias.name}")
                else:
                    yield from self._check(ctx, node, mod)

    @staticmethod
    def _absolute(ctx: FileContext, node: ast.ImportFrom) -> Optional[str]:
        if not node.level:
            return node.module or None
        # resolve `from ..core import store` against the file's module
        parts = ctx.module_name().split(".")
        if len(parts) < node.level:
            return node.module or None
        base = parts[:len(parts) - node.level]
        return ".".join(base + ([node.module] if node.module else []))

    def _check(self, ctx, node, mod: str) -> Iterable[Finding]:
        parts = mod.split(".")
        if (len(parts) >= 3 and parts[0] == "repro" and parts[1] == "core"
                and parts[2] in RESTRICTED_CORE):
            yield self._finding(ctx, node, ".".join(parts[:3]))

    def _finding(self, ctx, node, mod: str) -> Finding:
        return Finding(self.id, ctx.posix, node.lineno, node.col_offset,
                       f"import of {mod} bypasses repro.api "
                       "(core internals are core/api-only)")


# ---------------------------------------------------------------------------
# 2. layering-index — the former check.sh index grep gate, on identifiers
# ---------------------------------------------------------------------------

INDEX_TOKENS = ("dir_keys", "dir_leaf", "searchsorted")
INDEX_ALLOWED_FILES = ("repro/core/index.py", "repro/core/backend.py",
                       "repro/core/baseline.py")


class LayeringIndexRule(Rule):
    id = "layering-index"
    description = (
        "flat-directory / descent internals (dir_keys, dir_leaf, "
        "searchsorted) are confined to core/index.py + core/backend.py "
        "(+ the uruv_search kernels and the flat baseline); ordinal and "
        "rank access goes through repro.core.index helpers")

    def check_file(self, ctx: FileContext) -> Iterable[Finding]:
        p = "/" + ctx.posix
        if any(p.endswith("/" + f) for f in INDEX_ALLOWED_FILES):
            return
        if ctx.in_dir("repro/kernels/uruv_search"):
            return
        for node in ast.walk(ctx.tree):
            tok = None
            if isinstance(node, ast.Name) and node.id in INDEX_TOKENS:
                tok = node.id
            elif isinstance(node, ast.Attribute) and node.attr in INDEX_TOKENS:
                tok = node.attr
            elif isinstance(node, ast.arg) and node.arg in INDEX_TOKENS:
                tok = node.arg
            elif (isinstance(node, ast.keyword)
                  and node.arg in INDEX_TOKENS):
                tok = node.arg
            elif isinstance(node, ast.alias) and node.name in INDEX_TOKENS:
                tok = node.name
            if tok is not None:
                yield Finding(
                    self.id, ctx.posix, getattr(node, "lineno", 0),
                    getattr(node, "col_offset", 0),
                    f"descent internal '{tok}' used outside "
                    "core/index.py + core/backend.py "
                    "(use repro.core.index.rank()/ordinal helpers)")


# ---------------------------------------------------------------------------
# 3. device-pass-purity — no host syncs inside @device_pass hot paths
# ---------------------------------------------------------------------------

HOST_SYNC_METHODS = ("item", "tolist", "block_until_ready")
HOST_CASTS = ("int", "float", "bool")


def _device_pass_static(fn: ast.AST) -> Optional[Tuple[str, ...]]:
    """The decorator's static-parameter tuple when ``fn`` is marked
    ``@device_pass`` (any syntactic spelling); None when unmarked."""
    for dec in getattr(fn, "decorator_list", ()):
        target, static = dec, ()
        if isinstance(dec, ast.Call):
            target = dec.func
            for kw in dec.keywords:
                if kw.arg == "static":
                    elts = getattr(kw.value, "elts", None)
                    if elts is not None:
                        static = tuple(
                            e.value for e in elts
                            if isinstance(e, ast.Constant))
                    elif isinstance(kw.value, ast.Constant):
                        static = (kw.value.value,)
        name = (target.attr if isinstance(target, ast.Attribute)
                else getattr(target, "id", None))
        if name == "device_pass":
            return tuple(static)
    return None


def _param_names(fn) -> List[str]:
    a = fn.args
    names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    return [n for n in names if n not in ("self", "cls")]


def _names_outside_none_checks(test: ast.AST) -> Set[str]:
    """Name loads in a condition, skipping ``x is None`` comparisons
    (branching on an optional argument is host-static, not a sync)."""
    out: Set[str] = set()

    def visit(node):
        if (isinstance(node, ast.Compare)
                and all(isinstance(op, (ast.Is, ast.IsNot))
                        for op in node.ops)
                and all(isinstance(c, ast.Constant) and c.value is None
                        for c in node.comparators)):
            return
        if isinstance(node, ast.Name):
            out.add(node.id)
        for child in ast.iter_child_nodes(node):
            visit(child)

    visit(test)
    return out


class DevicePassPurityRule(Rule):
    id = "device-pass-purity"
    description = (
        "inside a @device_pass function, host syncs are errors: .item() "
        "/ .tolist() / block_until_ready / jax.device_get, int()/float()"
        "/bool() on non-literals, np.asarray/np.array, and Python "
        "if/while on a non-static parameter (a traced value)")

    def check_file(self, ctx: FileContext) -> Iterable[Finding]:
        for fn in ast.walk(ctx.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            static = _device_pass_static(fn)
            if static is None:
                continue
            yield from self._check_fn(ctx, fn, set(static))

    def _check_fn(self, ctx, fn, static: Set[str]) -> Iterable[Finding]:
        traced = set(_param_names(fn)) - static
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                msg = self._call_violation(node)
                if msg:
                    yield Finding(self.id, ctx.posix, node.lineno,
                                  node.col_offset,
                                  f"{msg} in device pass '{fn.name}'")
            elif isinstance(node, (ast.If, ast.While)):
                hot = _names_outside_none_checks(node.test) & traced
                if hot:
                    kind = "if" if isinstance(node, ast.If) else "while"
                    yield Finding(
                        self.id, ctx.posix, node.lineno, node.col_offset,
                        f"Python `{kind}` on traced parameter(s) "
                        f"{sorted(hot)} in device pass '{fn.name}' "
                        "(use lax.cond/jnp.where, or declare the "
                        "parameter jit-static via device_pass(static=...))")

    @staticmethod
    def _call_violation(node: ast.Call) -> Optional[str]:
        f = node.func
        if isinstance(f, ast.Attribute):
            if f.attr in HOST_SYNC_METHODS:
                return f"host sync `.{f.attr}()`"
            if f.attr == "device_get" and _root_name(f) == "jax":
                return "host sync `jax.device_get`"
            if (f.attr in ("asarray", "array")
                    and _root_name(f) in ("np", "numpy")):
                return f"host transfer `np.{f.attr}()`"
        elif isinstance(f, ast.Name) and f.id in HOST_CASTS:
            if node.args and not all(
                    isinstance(a, ast.Constant) for a in node.args):
                return f"host sync `{f.id}()` on a non-literal"
        return None


# ---------------------------------------------------------------------------
# 4. donation-safety — no use of a buffer after it was donated
# ---------------------------------------------------------------------------

class DonationSafetyRule(Rule):
    id = "donation-safety"
    description = (
        "a store passed to a donate_argnums callee (donate_store=True / "
        "a function defined with donate_argnums) is invalidated: any "
        "later use in the same scope before rebinding is an error — the "
        "generalized _bulk_apply_dstore rollback hazard of DESIGN.md "
        "Sec 12")

    def check_file(self, ctx: FileContext) -> Iterable[Finding]:
        donating = self._donating_defs(ctx.tree)
        for fn in ast.walk(ctx.tree):
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                findings: List[Finding] = []
                self._walk_block(ctx, fn.body, set(), donating, findings)
                yield from findings

    @staticmethod
    def _donating_defs(tree) -> Dict[str, Tuple[int, ...]]:
        """Functions defined in this module with jit donate_argnums —
        their call sites donate the listed positional args."""
        out: Dict[str, Tuple[int, ...]] = {}
        for fn in ast.walk(tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            for dec in fn.decorator_list:
                for kw in getattr(dec, "keywords", ()):
                    if kw.arg != "donate_argnums":
                        continue
                    elts = getattr(kw.value, "elts", None)
                    if elts is None and isinstance(kw.value, ast.Constant):
                        elts = [kw.value]
                    if elts:
                        out[fn.name] = tuple(
                            e.value for e in elts
                            if isinstance(e, ast.Constant))
        return out

    def _walk_block(self, ctx, stmts, tainted: Set[str], donating,
                    findings: List[Finding]) -> Set[str]:
        for stmt in stmts:
            # compound statements: process only the header expression
            # here, then recurse so body statements see taint in order
            # (branches fork the taint; loops run twice for wraparound)
            if isinstance(stmt, ast.If):
                self._scan_expr(ctx, stmt.test, tainted, donating, findings)
                t1 = self._walk_block(ctx, stmt.body, set(tainted),
                                      donating, findings)
                t2 = self._walk_block(ctx, stmt.orelse, set(tainted),
                                      donating, findings)
                tainted = t1 | t2
            elif isinstance(stmt, (ast.For, ast.AsyncFor, ast.While)):
                header = stmt.iter if hasattr(stmt, "iter") else stmt.test
                self._scan_expr(ctx, header, tainted, donating, findings)
                body = stmt.body + stmt.orelse
                for _ in range(2):
                    tainted |= self._walk_block(ctx, body, set(tainted),
                                                donating, findings)
            elif isinstance(stmt, (ast.With, ast.AsyncWith)):
                for item in stmt.items:
                    self._scan_expr(ctx, item.context_expr, tainted,
                                    donating, findings)
                tainted = self._walk_block(ctx, stmt.body, tainted,
                                           donating, findings)
            elif isinstance(stmt, ast.Try):
                for block in (stmt.body, stmt.orelse, stmt.finalbody,
                              *[h.body for h in stmt.handlers]):
                    tainted = self._walk_block(ctx, block, tainted,
                                               donating, findings)
            elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                   ast.ClassDef)):
                continue        # nested scopes are analyzed on their own
            else:
                self._scan_expr(ctx, stmt, tainted, donating, findings)
                for key in self._assigned_keys(stmt):
                    tainted.discard(key)
        return tainted

    def _scan_expr(self, ctx, node, tainted: Set[str], donating,
                   findings: List[Finding]) -> None:
        """Flag loads of tainted keys in ``node``, then add the taints
        its donating calls introduce (uses in the donating statement
        itself are pre-donation and stay legal)."""
        if tainted:
            for sub in ast.walk(node):
                if not isinstance(sub, (ast.Name, ast.Attribute)):
                    continue
                if not isinstance(getattr(sub, "ctx", None), ast.Load):
                    continue
                key = _expr_key(sub)
                # exact match suffices: a use through a longer chain
                # (self._store.ts) walks the tainted sub-node itself
                if key is not None and key in tainted:
                    findings.append(Finding(
                        self.id, ctx.posix, sub.lineno, sub.col_offset,
                        f"use of '{key}' after it was donated into a "
                        "device pass (donated buffers are invalidated; "
                        "rebind from the pass result)"))
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                tainted |= self._donated_args(sub, donating)

    @staticmethod
    def _donated_args(call: ast.Call, donating) -> Set[str]:
        fname = (call.func.attr if isinstance(call.func, ast.Attribute)
                 else getattr(call.func, "id", None))
        out: Set[str] = set()
        # exact knowledge: the callee is defined in this module with
        # donate_argnums — taint the listed positional args verbatim
        for pos in donating.get(fname, ()):
            if pos < len(call.args):
                key = _expr_key(call.args[pos])
                if key is not None:
                    out.add(key)
        # heuristic: a call carrying donate_store=<truthy-or-unknown>
        # donates its store argument; only store-named args are tainted
        # (a client-level call like db.apply_nowait(plan, donate_store=x)
        # donates db's INTERNAL store, which the client rebinds itself)
        for kw in call.keywords:
            if kw.arg != "donate_store":
                continue
            if isinstance(kw.value, ast.Constant) and not kw.value.value:
                continue                # donate_store=False
            for arg in call.args:
                key = _expr_key(arg)
                if key is not None and "store" in key.rsplit(".", 1)[-1]:
                    out.add(key)
        return out

    @staticmethod
    def _assigned_keys(stmt) -> Set[str]:
        out: Set[str] = set()
        targets: List[ast.AST] = []
        if isinstance(stmt, ast.Assign):
            targets = list(stmt.targets)
        elif isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
            targets = [stmt.target]
        for t in targets:
            for node in ast.walk(t):
                key = _expr_key(node)
                if key is not None:
                    out.add(key)
        return out


# ---------------------------------------------------------------------------
# 5. determinism — no wall clock / host RNG in the op_ts plumbing
# ---------------------------------------------------------------------------

DETERMINISM_SCOPE = ("repro/core", "repro/durability")
NONDET_MODULES = ("time", "random", "secrets", "uuid")


class DeterminismRule(Rule):
    id = "determinism"
    description = (
        "bit-exact sharded == local timestamps are a gated invariant: "
        "core modules (the op_ts plumbing and sharded apply paths) and "
        "the durability package (crash recovery replays the WAL at its "
        "recorded timestamps) must not read the wall clock, host RNGs "
        "(random.*, np.random.*, os.urandom), or iterate sets "
        "(jax.random with explicit keys is fine)")

    def check_file(self, ctx: FileContext) -> Iterable[Finding]:
        if not ctx.in_dir(*DETERMINISM_SCOPE):
            return
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.name.split(".")[0] in NONDET_MODULES:
                        yield self._finding(ctx, node, alias.name,
                                            "import of")
            elif isinstance(node, ast.ImportFrom) and not node.level:
                mod = (node.module or "").split(".")[0]
                if mod in NONDET_MODULES:
                    yield self._finding(ctx, node, node.module, "import from")
            elif isinstance(node, ast.Attribute):
                root = _root_name(node)
                if root in NONDET_MODULES:
                    yield self._finding(ctx, node, f"{root}.{node.attr}",
                                        "use of")
                elif (root in ("np", "numpy") and node.attr == "random"):
                    yield self._finding(ctx, node, f"{root}.random",
                                        "use of")
                elif root == "os" and node.attr == "urandom":
                    # os is legitimate in durability (fsync, rename, kill)
                    # — only its entropy source is a replay hazard
                    yield self._finding(ctx, node, "os.urandom", "use of")
            elif isinstance(node, (ast.For, ast.comprehension)):
                it = node.iter
                if isinstance(it, ast.Set) or (
                        isinstance(it, ast.Call)
                        and getattr(it.func, "id", None) == "set"):
                    yield Finding(
                        self.id, ctx.posix, it.lineno, it.col_offset,
                        "iteration over a set has no deterministic order "
                        "in core (sort it)")

    def _finding(self, ctx, node, what, verb) -> Finding:
        return Finding(self.id, ctx.posix, node.lineno, node.col_offset,
                       f"{verb} '{what}' in deterministic core "
                       "(timestamps/linearization must be replayable)")


# ---------------------------------------------------------------------------
# 6. kernel-parity — kernels/<k>/: kernel and ref twins agree
# ---------------------------------------------------------------------------

class KernelParityRule(Rule):
    id = "kernel-parity"
    description = (
        "each kernels/<k>/ package keeps kernel (<k>.py) and oracle "
        "(ref.py) twins signature-compatible: same positional parameter "
        "names in order, ref keyword-onlys a subset of the kernel's "
        "(the kernel may add block/interpret knobs)")

    def check_project(self, ctxs: Sequence[FileContext]) -> Iterable[Finding]:
        pkgs: Dict[str, Dict[str, FileContext]] = {}
        for ctx in ctxs:
            parts = ctx.posix.split("/")
            if "kernels" not in parts:
                continue
            i = parts.index("kernels")
            if len(parts) != i + 3:
                continue
            pkg, fname = parts[i + 1], parts[i + 2]
            pkgs.setdefault(pkg, {})[fname] = ctx
        for pkg, files in sorted(pkgs.items()):
            kctx = files.get(f"{pkg}.py")
            rctx = files.get("ref.py")
            if kctx is None or rctx is None:
                continue
            yield from self._check_pkg(pkg, kctx, rctx)

    def _check_pkg(self, pkg, kctx, rctx) -> Iterable[Finding]:
        kfns = self._publics(kctx.tree)
        rfns = self._publics(rctx.tree)
        for name, kfn in kfns.items():
            rfn = rfns.get(f"{name}_ref")
            if rfn is None and len(kfns) == 1 and len(rfns) == 1:
                rfn = next(iter(rfns.values()))     # sole-function pairing
            if rfn is None:
                yield Finding(
                    self.id, kctx.posix, kfn.lineno, kfn.col_offset,
                    f"kernel '{pkg}.{name}' has no oracle twin "
                    f"'{name}_ref' in ref.py")
                continue
            kpos, kkw = self._sig(kfn)
            rpos, rkw = self._sig(rfn)
            if kpos != rpos:
                yield Finding(
                    self.id, kctx.posix, kfn.lineno, kfn.col_offset,
                    f"kernel '{pkg}.{name}' positional params {kpos} != "
                    f"ref twin '{rfn.name}' params {rpos}")
            extra = set(rkw) - set(kkw)
            if extra:
                yield Finding(
                    self.id, rctx.posix, rfn.lineno, rfn.col_offset,
                    f"ref '{rfn.name}' keyword-only params {sorted(extra)} "
                    f"missing from kernel '{pkg}.{name}'")

    @staticmethod
    def _publics(tree) -> Dict[str, ast.FunctionDef]:
        return {n.name: n for n in tree.body
                if isinstance(n, ast.FunctionDef)
                and not n.name.startswith("_")}

    @staticmethod
    def _sig(fn) -> Tuple[Tuple[str, ...], Tuple[str, ...]]:
        a = fn.args
        return (tuple(p.arg for p in a.posonlyargs + a.args),
                tuple(p.arg for p in a.kwonlyargs))


# ---------------------------------------------------------------------------
# 7. kernel-vmem — BlockSpec footprint of a pallas_call under budget
# ---------------------------------------------------------------------------

DEFAULT_VMEM_BUDGET = 16 * 1024 * 1024      # conservative VMEM per call
_ELEM_BYTES = 4                             # int32/float32 pools


class KernelVmemRule(Rule):
    id = "kernel-vmem"
    description = (
        "per pallas_call, the summed footprint of BlockSpec block shapes "
        "(bounded dims only: literals, keyword defaults, min() bounds) "
        "must stay under the VMEM budget; full-array specs with "
        "runtime-sized dims are skipped")

    def __init__(self, budget: int = DEFAULT_VMEM_BUDGET):
        self.budget = budget

    def check_file(self, ctx: FileContext) -> Iterable[Finding]:
        if not ctx.in_dir("repro/kernels"):
            return
        for fn in ast.walk(ctx.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            env = self._bound_env(fn)
            for node in ast.walk(fn):
                if (isinstance(node, ast.Call)
                        and isinstance(node.func, (ast.Attribute, ast.Name))
                        and (getattr(node.func, "attr", None)
                             or getattr(node.func, "id", None))
                        == "pallas_call"):
                    yield from self._check_call(ctx, fn, node, env)

    def _check_call(self, ctx, fn, call, env) -> Iterable[Finding]:
        total, unbounded = 0, 0
        for node in ast.walk(call):
            if not (isinstance(node, ast.Call)
                    and (getattr(node.func, "attr", None)
                         or getattr(node.func, "id", None)) == "BlockSpec"):
                continue
            if not node.args:
                continue
            shape = node.args[0]
            if not isinstance(shape, (ast.Tuple, ast.List)):
                unbounded += 1
                continue
            n = 1
            for dim in shape.elts:
                v = self._bound(dim, env)
                if v is None:
                    n = None
                    break
                n *= max(v, 0)
            if n is None:
                unbounded += 1
            else:
                total += n * _ELEM_BYTES
        if total > self.budget:
            yield Finding(
                self.id, ctx.posix, call.lineno, call.col_offset,
                f"pallas_call in '{fn.name}' stages ~{total} bytes of "
                f"bounded BlockSpecs (budget {self.budget}; "
                f"{unbounded} unbounded specs not counted) — shrink the "
                "block shapes or raise --vmem-budget")

    def _bound_env(self, fn) -> Dict[str, int]:
        """Upper bounds for local names: int keyword defaults, constant
        assignments, and min() of any known bound (min <= each arg)."""
        env: Dict[str, int] = {}
        a = fn.args
        kw = a.args[len(a.args) - len(a.defaults):] + a.kwonlyargs
        for p, d in zip(kw, list(a.defaults) + list(a.kw_defaults)):
            if isinstance(d, ast.Constant) and type(d.value) is int:
                env[p.arg] = d.value
        for stmt in ast.walk(fn):
            if (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                    and isinstance(stmt.targets[0], ast.Name)):
                v = self._bound(stmt.value, env)
                if v is not None:
                    env[stmt.targets[0].id] = v
        return env

    def _bound(self, node, env) -> Optional[int]:
        if isinstance(node, ast.Name):
            return env.get(node.id)
        if (isinstance(node, ast.Call)
                and getattr(node.func, "id", None) == "min" and node.args):
            known = [self._bound(a, env) for a in node.args]
            known = [k for k in known if k is not None]
            return min(known) if known else None
        v = _const_eval(node)
        if v is not None:
            return v
        if isinstance(node, ast.BinOp):
            a, b = self._bound(node.left, env), self._bound(node.right, env)
            if a is None or b is None:
                return None
            if isinstance(node.op, ast.Mult):
                return a * b
            if isinstance(node.op, ast.Add):
                return a + b
        return None


# ---------------------------------------------------------------------------
# 8. sentinel-literal — key sentinels only in the blessed domain module
# ---------------------------------------------------------------------------

# KEY_MAX (mask-out / padding), KEY_MAX - 1 (the kernels' internal pad),
# KEY_MAX - 2 (largest user-visible key): a literal spelling of any of
# these outside core/ref.py is exactly the bug class opbatch.check_keys
# exists for (PR 7's silent-loss fix)
SENTINEL_VALUES = (KEY_MAX, KEY_MAX - 1, KEY_MAX - 2)
SENTINEL_BLESSED = ("repro/core/ref.py",)


class SentinelLiteralRule(Rule):
    id = "sentinel-literal"
    description = (
        "key-sentinel literals (2**31-1 / 0x7FFFFFFF masks and the "
        "derived pad/domain values) may be spelled only in core/ref.py; "
        "everywhere else import KEY_MAX / KEY_DOMAIN_HI (repro.api "
        "re-exports them)")

    def check_file(self, ctx: FileContext) -> Iterable[Finding]:
        p = "/" + ctx.posix
        if any(p.endswith("/" + f) for f in SENTINEL_BLESSED):
            return
        for node in ast.walk(ctx.tree):
            if not isinstance(node, (ast.BinOp, ast.Constant)):
                continue
            v = _const_eval(node)
            if v is None or v not in SENTINEL_VALUES:
                continue
            # flag the OUTERMOST folded expression only: skip constants
            # whose value can't be told apart from a nested fold — handled
            # by dedup in the engine via identical (line, col) spans
            yield Finding(
                self.id, ctx.posix, node.lineno, node.col_offset,
                f"key-sentinel literal {v} (= KEY_MAX - {KEY_MAX - v}) "
                "outside core/ref.py — import KEY_MAX / KEY_DOMAIN_HI "
                "from repro.api instead")


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def default_rules(vmem_budget: int = DEFAULT_VMEM_BUDGET) -> List[Rule]:
    return [
        LayeringApiRule(),
        LayeringIndexRule(),
        DevicePassPurityRule(),
        DonationSafetyRule(),
        DeterminismRule(),
        KernelParityRule(),
        KernelVmemRule(vmem_budget),
        SentinelLiteralRule(),
    ]


ALL_RULE_CLASSES = (
    LayeringApiRule, LayeringIndexRule, DevicePassPurityRule,
    DonationSafetyRule, DeterminismRule, KernelParityRule, KernelVmemRule,
    SentinelLiteralRule,
)
