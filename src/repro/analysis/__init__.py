"""repro.analysis — uruvlint, the repo's structural-invariant prover.

Every headline claim (one-device-pass CRUD, bit-exact sharded == local
timestamps, zero-host-sync pipelined serving) is a *structural* property
of the source; this package checks those properties by AST analysis
instead of runtime luck or grep gates:

  * ``python -m repro.analysis src/``       lint (exit 1 on findings)
  * ``python -m repro.analysis --format=json``  machine-diffable report
  * ``@repro.analysis.device_pass``         mark a jitted hot path whose
    body must stay free of host syncs (the purity rule's registry)

Rule catalog, suppression syntax (``# uruvlint: disable=<rule>``) and
the how-to-add-a-rule recipe: DESIGN.md Sec 13.  Only :mod:`marks` is
imported eagerly so that ``repro.core`` can register device passes
without pulling the linter into the hot-path import graph; the engine
loads on first attribute access.
"""

from repro.analysis.marks import DEVICE_PASS_REGISTRY, device_pass

__all__ = [
    "DEVICE_PASS_REGISTRY",
    "device_pass",
    "run_paths",
]


def __getattr__(name):
    if name == "run_paths":
        from repro.analysis.engine import run_paths

        return run_paths
    raise AttributeError(name)
