"""uruvlint CLI: ``python -m repro.analysis [paths...]``.

Exit code 1 iff any error-severity finding survives inline suppressions
and the tracked allowlist — scripts/check.sh runs this before the test
tiers, so a layering / purity / donation / sentinel regression fails CI
before a single test executes (DESIGN.md Sec 13).
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.analysis.engine import Allowlist, load_contexts, run_contexts
from repro.analysis.reporters import exit_code, render_json, render_text
from repro.analysis.rules import DEFAULT_VMEM_BUDGET, default_rules

DEFAULT_PATHS = ("src/repro", "benchmarks", "examples", "scripts")
DEFAULT_ALLOWLIST = Path("scripts/uruvlint_allow.txt")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="uruvlint: prove Uruv's structural invariants "
                    "(layering, device-pass purity, donation safety, "
                    "determinism, kernel checks) by static analysis.")
    ap.add_argument("paths", nargs="*", help="files or directories "
                    f"(default: {' '.join(DEFAULT_PATHS)})")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--select", help="comma-separated rule ids to run")
    ap.add_argument("--disable", help="comma-separated rule ids to skip")
    ap.add_argument("--allowlist", type=Path, default=None,
                    help=f"tracked deferral file (default: "
                         f"{DEFAULT_ALLOWLIST} when present)")
    ap.add_argument("--vmem-budget", type=int, default=DEFAULT_VMEM_BUDGET,
                    help="kernel-vmem byte budget per pallas_call")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    rules = default_rules(vmem_budget=args.vmem_budget)
    if args.list_rules:
        for r in rules:
            print(f"{r.id:20s} {' '.join(r.description.split())}")
        return 0
    if args.select:
        keep = {s.strip() for s in args.select.split(",")}
        rules = [r for r in rules if r.id in keep]
    if args.disable:
        drop = {s.strip() for s in args.disable.split(",")}
        rules = [r for r in rules if r.id not in drop]

    paths = args.paths or [p for p in DEFAULT_PATHS if Path(p).exists()]
    allow = None
    allow_path = args.allowlist or (
        DEFAULT_ALLOWLIST if DEFAULT_ALLOWLIST.exists() else None)
    if allow_path is not None and allow_path.exists():
        allow = Allowlist.load(allow_path)

    ctxs, errors = load_contexts(paths)
    findings = errors + run_contexts(ctxs, rules, allow)
    render = render_json if args.format == "json" else render_text
    print(render(findings, len(ctxs)))
    return exit_code(findings)


if __name__ == "__main__":
    sys.exit(main())
