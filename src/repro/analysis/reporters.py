"""uruvlint reporters: human text and machine-diffable JSON.

The JSON shape is stable so future PRs can diff finding counts:

    {"version": 1, "files": N, "counts": {"<rule>": n, ...},
     "findings": [{"rule", "path", "line", "col", "severity",
                   "message"}, ...]}
"""

from __future__ import annotations

import json
from typing import Dict, List, Sequence

from repro.analysis.engine import ERROR, Finding


def counts_by_rule(findings: Sequence[Finding]) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for f in findings:
        out[f.rule] = out.get(f.rule, 0) + 1
    return dict(sorted(out.items()))


def render_text(findings: Sequence[Finding], n_files: int) -> str:
    lines: List[str] = [f.render() for f in findings]
    if findings:
        per_rule = ", ".join(f"{r}={n}"
                             for r, n in counts_by_rule(findings).items())
        lines.append(f"uruvlint: {len(findings)} finding(s) in "
                     f"{n_files} file(s) [{per_rule}]")
    else:
        lines.append(f"uruvlint: clean ({n_files} file(s))")
    return "\n".join(lines)


def render_json(findings: Sequence[Finding], n_files: int) -> str:
    return json.dumps({
        "version": 1,
        "files": n_files,
        "counts": counts_by_rule(findings),
        "findings": [
            {"rule": f.rule, "path": f.path, "line": f.line, "col": f.col,
             "severity": f.severity, "message": f.message}
            for f in findings
        ],
    }, indent=2)


def exit_code(findings: Sequence[Finding]) -> int:
    return 1 if any(f.severity == ERROR for f in findings) else 0
