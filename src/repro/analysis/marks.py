"""Dependency-free source markers consumed by uruvlint (`repro.analysis`).

``@device_pass`` declares a function to be a DEVICE PASS: a jitted hot
path in which a host synchronization (``.item()``, ``int()/float()/
bool()`` on array values, ``np.asarray``, ``block_until_ready``, a
Python ``if`` on a traced value) would silently serialize the pipeline —
the structural property behind the repo's one-device-pass and
zero-host-sync claims (DESIGN.md Sec 3 / Sec 12 / Sec 13).

The decorator is an identity at runtime apart from recording the
function in :data:`DEVICE_PASS_REGISTRY`; the real enforcement is
static — uruvlint's ``device-pass-purity`` rule recognizes the
decorator syntactically and checks the decorated body.

``static=(...)`` names the parameters that are jit-static (backend
selectors, python bools baked into the trace): Python control flow on a
static parameter is fine and is not flagged.

This module imports nothing so that ``repro.core`` (and the kernels) can
depend on it without pulling the linter — or anything else — into the
hot-path import graph.
"""

from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

# qualified name ("module.qualname") -> names of jit-static parameters
DEVICE_PASS_REGISTRY: Dict[str, Tuple[str, ...]] = {}


def device_pass(fn: Optional[Callable] = None, *,
                static: Tuple[str, ...] = ()):
    """Mark ``fn`` as a device pass (registration contract: DESIGN.md
    Sec 13).  Usable bare (``@device_pass``) or with static parameter
    names (``@device_pass(static=("backend",))``); always returns the
    function unchanged."""

    def mark(f: Callable) -> Callable:
        key = "%s.%s" % (
            getattr(f, "__module__", "?"),
            getattr(f, "__qualname__", getattr(f, "__name__", "?")),
        )
        DEVICE_PASS_REGISTRY[key] = tuple(static)
        return f

    if fn is None:
        return mark
    return mark(fn)
