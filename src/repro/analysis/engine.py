"""uruvlint rule engine: AST visitor core, suppressions, the driver.

The engine is deliberately small: a :class:`Rule` produces
:class:`Finding`\\ s from parsed :class:`FileContext`\\ s; the driver
collects ``*.py`` files, applies every registered rule, and filters the
result through inline suppressions (``# uruvlint: disable=<rule>`` on
the finding's line, ``# uruvlint: disable-file=<rule>`` anywhere in the
file) and an optional tracked allowlist (``scripts/uruvlint_allow.txt``:
one ``<rule-id> <path-glob>`` pair per line).

Rules come in two kinds: per-file (``check_file``) and project-wide
(``check_project``, for cross-file invariants like kernel/ref signature
parity).  The catalog lives in ``repro.analysis.rules``; adding a rule
is subclassing :class:`Rule` and appending to ``ALL_RULES``
(DESIGN.md Sec 13).
"""

from __future__ import annotations

import ast
import dataclasses
import fnmatch
import io
import re
import tokenize
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

ERROR = "error"
WARNING = "warning"

_SUPPRESS = re.compile(r"uruvlint:\s*disable(?P<file>-file)?=(?P<rules>[\w\-, ]+)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation, anchored to a source line."""

    rule: str
    path: str
    line: int
    col: int
    message: str
    severity: str = ERROR

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: "
                f"{self.rule} [{self.severity}] {self.message}")


class FileContext:
    """One parsed source file plus its suppression map.

    ``posix`` is the path the layering/scoping helpers match against —
    repo-relative with forward slashes (fixture tests pass synthetic
    paths like ``src/repro/serve/x.py``).
    """

    def __init__(self, path: str, source: str):
        self.posix = path.replace("\\", "/")
        self.source = source
        self.tree = ast.parse(source)
        # line -> suppressed rule ids ("all" wildcards the line)
        self.line_suppressed: Dict[int, Set[str]] = {}
        self.file_suppressed: Set[str] = set()
        self._parse_suppressions()

    def _parse_suppressions(self) -> None:
        try:
            toks = tokenize.generate_tokens(io.StringIO(self.source).readline)
            for tok in toks:
                if tok.type != tokenize.COMMENT:
                    continue
                m = _SUPPRESS.search(tok.string)
                if not m:
                    continue
                rules = {r.strip() for r in m.group("rules").split(",")
                         if r.strip()}
                if m.group("file"):
                    self.file_suppressed |= rules
                else:
                    self.line_suppressed.setdefault(
                        tok.start[0], set()).update(rules)
        except tokenize.TokenError:
            pass

    def suppressed(self, finding: Finding) -> bool:
        if {finding.rule, "all"} & self.file_suppressed:
            return True
        line = self.line_suppressed.get(finding.line, set())
        return bool({finding.rule, "all"} & line)

    def in_dir(self, *fragments: str) -> bool:
        """True when the file lives under any ``fragment`` (a posix path
        fragment like ``repro/core``), anchored at a path boundary."""
        p = "/" + self.posix
        return any(f"/{frag.strip('/')}/" in p for frag in fragments)

    def is_file(self, *names: str) -> bool:
        p = "/" + self.posix
        return any(p.endswith("/" + n) for n in names)

    def module_name(self) -> str:
        """Dotted module path inferred from the file path (best effort:
        everything from the last ``repro`` segment on; used to resolve
        relative imports)."""
        parts = self.posix.split("/")
        if "repro" in parts:
            parts = parts[parts.index("repro"):]
        if parts[-1].endswith(".py"):
            parts[-1] = parts[-1][:-3]
        if parts[-1] == "__init__":
            parts = parts[:-1]
        return ".".join(parts)


class Rule:
    """Base rule: subclass, set ``id``/``description``, implement
    ``check_file`` (per file) and/or ``check_project`` (cross-file)."""

    id: str = "abstract"
    severity: str = ERROR
    description: str = ""

    def check_file(self, ctx: FileContext) -> Iterable[Finding]:
        return ()

    def check_project(self, ctxs: Sequence[FileContext]) -> Iterable[Finding]:
        return ()


class Allowlist:
    """Tracked deferrals: ``<rule-id> <path-glob>`` per line, ``#``
    comments.  Ships EMPTY (scripts/uruvlint_allow.txt) — an entry is a
    debt with its justification in the comment above it."""

    def __init__(self, entries: Sequence[Tuple[str, str]] = ()):
        self.entries = list(entries)

    @classmethod
    def load(cls, path: Path) -> "Allowlist":
        entries = []
        for raw in path.read_text().splitlines():
            line = raw.split("#", 1)[0].strip()
            if not line:
                continue
            rule, _, glob = line.partition(" ")
            entries.append((rule.strip(), glob.strip() or "*"))
        return cls(entries)

    def allows(self, finding: Finding) -> bool:
        return any(
            rule in (finding.rule, "all")
            and fnmatch.fnmatch(finding.path, glob)
            for rule, glob in self.entries
        )


def collect_files(paths: Sequence, root: Optional[Path] = None) -> List[Path]:
    files: List[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            files.append(p)
    return [f for f in files if "__pycache__" not in f.parts]


def load_contexts(paths: Sequence, root: Optional[Path] = None,
                  ) -> Tuple[List[FileContext], List[Finding]]:
    """Parse every file; unparsable files become findings, not crashes."""
    ctxs: List[FileContext] = []
    errors: List[Finding] = []
    for f in collect_files(paths):
        rel = f
        if root is not None:
            try:
                rel = f.resolve().relative_to(Path(root).resolve())
            except ValueError:
                rel = f
        try:
            ctxs.append(FileContext(str(rel), f.read_text()))
        except SyntaxError as e:
            errors.append(Finding("parse-error", str(rel), e.lineno or 0,
                                  e.offset or 0, f"syntax error: {e.msg}"))
    return ctxs, errors


def run_contexts(ctxs: Sequence[FileContext],
                 rules: Sequence[Rule],
                 allowlist: Optional[Allowlist] = None) -> List[Finding]:
    by_path = {c.posix: c for c in ctxs}
    findings: List[Finding] = []
    for rule in rules:
        for ctx in ctxs:
            findings.extend(rule.check_file(ctx))
        findings.extend(rule.check_project(list(ctxs)))
    out = []
    seen = set()
    for f in findings:
        key = (f.rule, f.path, f.line, f.col, f.message)
        if key in seen:
            continue
        seen.add(key)
        ctx = by_path.get(f.path)
        if ctx is not None and ctx.suppressed(f):
            continue
        if allowlist is not None and allowlist.allows(f):
            continue
        out.append(f)
    out.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return out


def run_paths(paths: Sequence, rules: Optional[Sequence[Rule]] = None,
              allowlist: Optional[Allowlist] = None,
              root: Optional[Path] = None) -> List[Finding]:
    """Lint ``paths`` (files or directories) and return the surviving
    findings — the programmatic twin of ``python -m repro.analysis``."""
    if rules is None:
        from repro.analysis.rules import default_rules

        rules = default_rules()
    ctxs, errors = load_contexts(paths, root=root)
    return errors + run_contexts(ctxs, rules, allowlist)
